//! One-sided RDMA verbs: the CN-side endpoint.
//!
//! An [`Endpoint`] is a coordinator's window onto the memory pool. Every
//! verb (a) executes against the target [`MemNode`]'s real memory and (b)
//! charges the cost model: CN NIC issue cost, half-RTT propagation, MN
//! RNIC queueing + service, half-RTT completion. Doorbell batching (paper
//! section 7.2) issues several WQEs in one PCIe doorbell and pays one RTT
//! for the batch; small writes are treated as inline (no extra DMA read,
//! folded into `cn_issue_ns`); CQ polling with selective signaling is
//! likewise folded into the issue constant.

use std::sync::Arc;

use crate::dm::clock::{TimeGate, VClock};
use crate::dm::memnode::MemNode;
use crate::dm::netconfig::NetConfig;
use crate::dm::rnic::Rnic;
use crate::Result;

/// One operation inside a doorbell batch.
#[derive(Debug)]
pub enum VerbOp {
    /// READ `len` bytes at `addr` into `out`.
    Read {
        /// MN byte address.
        addr: u64,
        /// Output buffer (its length is the read length).
        out: Vec<u8>,
    },
    /// WRITE `data` at `addr`.
    Write {
        /// MN byte address.
        addr: u64,
        /// Bytes to write.
        data: Vec<u8>,
    },
    /// 8B CAS at `addr`; `old` receives the previous value.
    Cas {
        /// MN byte address (8B aligned).
        addr: u64,
        /// Expected value.
        expect: u64,
        /// Replacement value.
        swap: u64,
        /// Out: value observed before the CAS.
        old: u64,
    },
    /// 8B FAA at `addr`; `old` receives the previous value.
    Faa {
        /// MN byte address (8B aligned).
        addr: u64,
        /// Addend.
        delta: u64,
        /// Out: value observed before the add.
        old: u64,
    },
}

impl VerbOp {
    fn svc(&self, net: &NetConfig) -> u64 {
        match self {
            VerbOp::Read { out, .. } => net.read_cost(out.len()),
            VerbOp::Write { data, .. } => net.write_cost(data.len()),
            VerbOp::Cas { .. } => net.cas_svc_ns,
            VerbOp::Faa { .. } => net.faa_svc_ns,
        }
    }

    fn execute(&mut self, mn: &MemNode) -> Result<()> {
        match self {
            VerbOp::Read { addr, out } => mn.read_bytes(*addr, out),
            VerbOp::Write { addr, data } => mn.write_bytes(*addr, data),
            VerbOp::Cas {
                addr,
                expect,
                swap,
                old,
            } => {
                *old = mn.cas_u64(*addr, *expect, *swap)?;
                Ok(())
            }
            VerbOp::Faa { addr, delta, old } => {
                *old = mn.faa_u64(*addr, *delta)?;
                Ok(())
            }
        }
    }
}

/// A coordinator's verb endpoint (shares the CN NIC with its siblings).
#[derive(Clone)]
pub struct Endpoint {
    /// Owning CN id.
    pub cn: usize,
    /// The CN-side NIC (shared by all coordinators on this CN).
    pub nic: Arc<Rnic>,
    /// Cost model.
    pub net: Arc<NetConfig>,
    /// Conservative-PDES gate: synced before every fabric charge so
    /// arrivals at shared queues are (nearly) ordered in virtual time.
    gate: Option<(Arc<TimeGate>, usize)>,
}

impl Endpoint {
    /// New endpoint.
    pub fn new(cn: usize, nic: Arc<Rnic>, net: Arc<NetConfig>) -> Self {
        Self {
            cn,
            nic,
            net,
            gate: None,
        }
    }

    /// Attach the run's time gate (coordinator id `gid`).
    pub fn attach_gate(&mut self, gate: Arc<TimeGate>, gid: usize) {
        self.gate = Some((gate, gid));
    }

    /// Publish + bound this coordinator's clock before touching a queue.
    #[inline]
    pub fn gate_sync(&self, clk: &VClock) {
        if let Some((gate, gid)) = &self.gate {
            gate.sync(*gid, clk.now());
        }
    }

    /// Split-phase issue, post half: `n_ops` WQEs written to the send
    /// queue with the doorbell deferred. The step-machine calls this when
    /// a frame stages a plan and yields; the NIC tracks the
    /// posted-but-unrung depth (see [`Rnic::posted_wqes`]).
    #[inline]
    pub fn post_wqes(&self, n_ops: u64) {
        self.nic.note_posted(n_ops);
    }

    /// Split-phase issue, ring half: a doorbell (set) covering `n_ops`
    /// previously posted WQEs rang — or the WQEs died with a crashed CN.
    #[inline]
    pub fn ring_posted(&self, n_ops: u64) {
        self.nic.note_rung_posted(n_ops);
    }

    /// Issue a doorbell batch of verbs to one MN; returns at batch
    /// completion (one RTT + queued service of every op). Results are in
    /// the mutated `ops`.
    pub fn doorbell(&self, mn: &MemNode, ops: &mut [VerbOp], clk: &mut VClock) -> Result<()> {
        if ops.is_empty() {
            return Ok(());
        }
        self.gate_sync(clk);
        self.nic.ring(ops.len() as u64);
        let t_issue = self.nic.charge(
            clk.now(),
            self.net.doorbell_ns + self.net.cn_issue_ns * ops.len() as u64,
        );
        let t_arrive = t_issue + self.net.rtt_ns / 2;
        let mut t_done = t_arrive;
        for op in ops.iter_mut() {
            t_done = mn.rnic.charge(t_arrive, op.svc(&self.net));
            op.execute(mn)?;
        }
        clk.catch_up(t_done + self.net.rtt_ns / 2);
        Ok(())
    }

    /// Completion-driven issue of one doorbell batch: like [`Self::doorbell`]
    /// but starts at an explicit virtual time and returns *per-op*
    /// completion times (MN service done + the return half-RTT) instead of
    /// advancing a single clock. This is the primitive cross-transaction
    /// coalescing builds on: several frames' ops share one doorbell, and
    /// each owning frame's clock advances only to the completion of its
    /// own ops (see [`crate::dm::opbatch::MergedBatch`]).
    ///
    /// `ride` marks a batch that extends a doorbell another plan already
    /// rang within the same coalescing window: the per-doorbell MMIO
    /// overhead is skipped and no new ring is counted.
    pub fn doorbell_timed(
        &self,
        mn: &MemNode,
        ops: &mut [VerbOp],
        t_start: u64,
        ride: bool,
    ) -> Result<Vec<u64>> {
        if ops.is_empty() {
            return Ok(Vec::new());
        }
        if ride {
            self.nic.note_coalesced(ops.len() as u64);
        } else {
            self.nic.ring(ops.len() as u64);
        }
        let overhead = if ride { 0 } else { self.net.doorbell_ns };
        let t_issue = self
            .nic
            .charge(t_start, overhead + self.net.cn_issue_ns * ops.len() as u64);
        let t_arrive = t_issue + self.net.rtt_ns / 2;
        let mut completions = Vec::with_capacity(ops.len());
        for op in ops.iter_mut() {
            let t_done = mn.rnic.charge(t_arrive, op.svc(&self.net));
            op.execute(mn)?;
            completions.push(t_done + self.net.rtt_ns / 2);
        }
        Ok(completions)
    }

    /// Fire-and-forget batch: charges the NICs but advances the caller's
    /// clock only by the issue cost (used for async unlocks, paper 5.1:
    /// "returns the result immediately after issuing remote unlock
    /// requests").
    pub fn doorbell_async(&self, mn: &MemNode, ops: &mut [VerbOp], clk: &mut VClock) -> Result<()> {
        if ops.is_empty() {
            return Ok(());
        }
        self.gate_sync(clk);
        self.nic.ring(ops.len() as u64);
        let t_issue = self.nic.charge(
            clk.now(),
            self.net.doorbell_ns + self.net.cn_issue_ns * ops.len() as u64,
        );
        let t_arrive = t_issue + self.net.rtt_ns / 2;
        for op in ops.iter_mut() {
            mn.rnic.charge(t_arrive, op.svc(&self.net));
            op.execute(mn)?;
        }
        clk.catch_up(t_issue);
        Ok(())
    }

    /// Single READ.
    pub fn read(&self, mn: &MemNode, addr: u64, len: usize, clk: &mut VClock) -> Result<Vec<u8>> {
        let mut ops = [VerbOp::Read {
            addr,
            out: vec![0u8; len],
        }];
        self.doorbell(mn, &mut ops, clk)?;
        match ops {
            [VerbOp::Read { out, .. }] => Ok(out),
            _ => unreachable!(),
        }
    }

    /// Single 8B READ.
    pub fn read_u64(&self, mn: &MemNode, addr: u64, clk: &mut VClock) -> Result<u64> {
        let b = self.read(mn, addr, 8, clk)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    /// Single WRITE.
    pub fn write(&self, mn: &MemNode, addr: u64, data: &[u8], clk: &mut VClock) -> Result<()> {
        let mut ops = [VerbOp::Write {
            addr,
            data: data.to_vec(),
        }];
        self.doorbell(mn, &mut ops, clk)
    }

    /// Single CAS; returns the old value (success iff old == expect).
    pub fn cas(
        &self,
        mn: &MemNode,
        addr: u64,
        expect: u64,
        swap: u64,
        clk: &mut VClock,
    ) -> Result<u64> {
        let mut ops = [VerbOp::Cas {
            addr,
            expect,
            swap,
            old: 0,
        }];
        self.doorbell(mn, &mut ops, clk)?;
        match ops {
            [VerbOp::Cas { old, .. }] => Ok(old),
            _ => unreachable!(),
        }
    }

    /// Single FAA; returns the old value.
    pub fn faa(&self, mn: &MemNode, addr: u64, delta: u64, clk: &mut VClock) -> Result<u64> {
        let mut ops = [VerbOp::Faa {
            addr,
            delta,
            old: 0,
        }];
        self.doorbell(mn, &mut ops, clk)?;
        match ops {
            [VerbOp::Faa { old, .. }] => Ok(old),
            _ => unreachable!(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Arc<MemNode>, Endpoint) {
        let mn = Arc::new(MemNode::new(0, 1 << 16));
        let ep = Endpoint::new(
            0,
            Arc::new(Rnic::new()),
            Arc::new(NetConfig::default()),
        );
        (mn, ep)
    }

    #[test]
    fn read_write_roundtrip_with_latency() {
        let (mn, ep) = setup();
        let r = mn.register(64).unwrap();
        let mut clk = VClock::zero();
        ep.write(&mn, r.base, b"hello word", &mut clk).unwrap();
        let t_after_write = clk.now();
        // One verb >= RTT.
        assert!(t_after_write >= ep.net.rtt_ns, "t={t_after_write}");
        let out = ep.read(&mn, r.base, 10, &mut clk).unwrap();
        assert_eq!(&out, b"hello word");
        assert!(clk.now() > t_after_write);
    }

    #[test]
    fn cas_verbs_cost_more_than_writes() {
        let (mn, ep) = setup();
        let r = mn.register(16).unwrap();
        let mut c1 = VClock::zero();
        ep.write(&mn, r.base, &7u64.to_le_bytes(), &mut c1).unwrap();
        let mut c2 = VClock::zero();
        // fresh node so queues are empty
        let mn2 = Arc::new(MemNode::new(1, 1 << 12));
        let r2 = mn2.register(16).unwrap();
        ep.cas(&mn2, r2.base, 0, 1, &mut c2).unwrap();
        assert!(
            c2.now() > c1.now(),
            "CAS ({}) must cost more than WRITE ({})",
            c2.now(),
            c1.now()
        );
    }

    #[test]
    fn doorbell_batch_pays_one_rtt() {
        let (mn, ep) = setup();
        let r = mn.register(256).unwrap();
        // 8 writes batched
        let mut clk_batch = VClock::zero();
        let mut ops: Vec<VerbOp> = (0..8)
            .map(|i| VerbOp::Write {
                addr: r.base + i * 8,
                data: vec![i as u8; 8],
            })
            .collect();
        ep.doorbell(&mn, &mut ops, &mut clk_batch).unwrap();

        // 8 writes sequential on a fresh fabric
        let mn2 = Arc::new(MemNode::new(1, 1 << 12));
        let ep2 = Endpoint::new(0, Arc::new(Rnic::new()), ep.net.clone());
        let r2 = mn2.register(256).unwrap();
        let mut clk_seq = VClock::zero();
        for i in 0..8u64 {
            ep2.write(&mn2, r2.base + i * 8, &[0u8; 8], &mut clk_seq).unwrap();
        }
        assert!(
            clk_batch.now() * 4 < clk_seq.now(),
            "batch {} vs seq {}",
            clk_batch.now(),
            clk_seq.now()
        );
    }

    #[test]
    fn cas_atomicity_under_contention() {
        let (mn, _) = setup();
        let r = mn.register(8).unwrap();
        let mn2 = mn.clone();
        let addr = r.base;
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let mn = mn2.clone();
                std::thread::spawn(move || {
                    let ep = Endpoint::new(
                        0,
                        Arc::new(Rnic::new()),
                        Arc::new(NetConfig::default()),
                    );
                    let mut wins = 0;
                    let mut clk = VClock::zero();
                    for _ in 0..1000 {
                        // spin-increment via CAS
                        loop {
                            let cur = ep.read_u64(&mn, addr, &mut clk).unwrap();
                            if ep.cas(&mn, addr, cur, cur + 1, &mut clk).unwrap() == cur {
                                wins += 1;
                                break;
                            }
                        }
                    }
                    wins
                })
            })
            .collect();
        let total: u64 = threads.into_iter().map(|t| t.join().unwrap()).sum();
        assert_eq!(total, 8000);
        assert_eq!(mn.load_u64(addr).unwrap(), 8000);
    }

    #[test]
    fn async_doorbell_does_not_block_caller() {
        let (mn, ep) = setup();
        let r = mn.register(64).unwrap();
        let mut clk = VClock::zero();
        let mut ops = vec![VerbOp::Write {
            addr: r.base,
            data: vec![9u8; 8],
        }];
        ep.doorbell_async(&mn, &mut ops, &mut clk).unwrap();
        // Caller clock advanced far less than an RTT...
        assert!(clk.now() < ep.net.rtt_ns / 2);
        // ...but the write really happened.
        assert_eq!(mn.load_u64(r.base).unwrap(), u64::from_le_bytes([9; 8]));
    }

    #[test]
    fn faa_returns_old() {
        let (mn, ep) = setup();
        let r = mn.register(8).unwrap();
        let mut clk = VClock::zero();
        assert_eq!(ep.faa(&mn, r.base, 2, &mut clk).unwrap(), 0);
        assert_eq!(ep.faa(&mn, r.base, 2, &mut clk).unwrap(), 2);
    }
}
