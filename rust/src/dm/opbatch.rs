//! `OpBatch` — the shared one-sided doorbell-batch planner.
//!
//! Every multi-op exchange with the memory pool follows the same shape:
//! collect READ/WRITE/CAS/FAA verbs addressed at possibly-several MNs,
//! group them **per target MN**, issue each group as one doorbell batch
//! (one RTT + queued per-op service, paper §7.2), and map results back to
//! the logical operations that requested them. Before this module, that
//! plumbing was re-implemented ad hoc in every protocol phase of the
//! LOTUS coordinator *and* in every baseline; `OpBatch` is the single
//! implementation all of them plan through.
//!
//! Usage:
//!
//! ```
//! use std::sync::Arc;
//! use lotus::dm::{Endpoint, MemNode, NetConfig, OpBatch, Rnic, VClock};
//!
//! let mn = Arc::new(MemNode::new(0, 4096));
//! let region = mn.register(64).unwrap();
//! let ep = Endpoint::new(0, Arc::new(Rnic::new()), Arc::new(NetConfig::default()));
//! let mut clk = VClock::zero();
//!
//! let mut batch = OpBatch::new();
//! let w = batch.write(0, region.base, 7u64.to_le_bytes().to_vec());
//! let r = batch.read(0, region.base, 8);
//! let res = batch.issue(&ep, std::slice::from_ref(&mn), &mut clk).unwrap();
//! assert_eq!(res.read_buf(r), &7u64.to_le_bytes()[..]);
//! # let _ = w;
//! ```
//!
//! Guarantees relied on by the protocol code:
//!
//! - **Grouping**: ops targeting the same MN share one doorbell batch;
//!   groups are issued in first-use order of the MNs, and ops within a
//!   group stay in enqueue order. Cost charges are therefore *identical*
//!   to hand-built per-MN `VerbOp` vectors.
//! - **Tags**: each enqueue returns an [`OpTag`] naming the logical op;
//!   [`BatchResult`] resolves a tag to its buffer / old-value regardless
//!   of how the ops were grouped.
//! - **Async**: [`OpBatch::issue_async`] is the fire-and-forget variant
//!   (charges the NICs, advances the caller's clock only by the issue
//!   cost) used for unlock-style messages off the critical path.

use std::sync::Arc;

use crate::dm::clock::VClock;
use crate::dm::memnode::MemNode;
use crate::dm::verbs::{Endpoint, VerbOp};
use crate::Result;

/// Handle naming one enqueued op; resolves results in a [`BatchResult`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpTag(usize);

/// Caller-owned scratch for READ result buffers, reused across doorbell
/// rings (ROADMAP #4 follow-on (b)).
///
/// [`OpBatch::read`] allocates a fresh `vec![0u8; len]` per planned READ;
/// on the hot path that is one heap allocation per record per round,
/// every round, for buffers that are parsed and dropped microseconds
/// later. A `BufPool` breaks the cycle: plan READs with
/// [`OpBatch::read_pooled`], harvest results as usual, then hand buffers
/// back with [`BufPool::put`] / [`BatchResult::recycle`] — the next ring
/// reuses their capacity instead of hitting the allocator.
///
/// The pool is owned by the coordinator (one per sequential coordinator,
/// one per pipelined lane machine) and threaded through
/// [`crate::txn::phases::PhaseCtx`]; buffers survive the merge/split
/// round trip of a [`MergedBatch`] untouched, so pooling composes with
/// doorbell coalescing. Purely a host-allocator optimisation: buffer
/// *contents* and every virtual-time charge are identical with or
/// without the pool.
#[derive(Debug, Default)]
pub struct BufPool {
    free: Vec<Vec<u8>>,
    /// READs served from the free list (vs fresh allocations).
    reuses: u64,
}

impl BufPool {
    /// An empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// A zeroed buffer of exactly `len` bytes — recycled capacity when
    /// the free list has any, a fresh allocation otherwise.
    pub fn get(&mut self, len: usize) -> Vec<u8> {
        match self.free.pop() {
            Some(mut b) => {
                self.reuses += 1;
                b.clear();
                b.resize(len, 0);
                b
            }
            None => vec![0u8; len],
        }
    }

    /// Return a buffer's capacity to the free list.
    pub fn put(&mut self, b: Vec<u8>) {
        if b.capacity() > 0 {
            self.free.push(b);
        }
    }

    /// Buffers currently on the free list.
    pub fn available(&self) -> usize {
        self.free.len()
    }

    /// READs served from recycled capacity since construction.
    pub fn reuses(&self) -> u64 {
        self.reuses
    }
}

/// Sentinel for "this MN has no group yet" in the per-MN group index.
const NO_GROUP: u32 = u32::MAX;

/// A planned set of one-sided ops, grouped per target MN.
#[derive(Debug, Default)]
pub struct OpBatch {
    /// Per-MN groups in first-use order: `(mn id, ops)`.
    groups: Vec<(usize, Vec<VerbOp>)>,
    /// tag index -> (group index, op index within the group).
    index: Vec<(usize, usize)>,
    /// MN id -> group index (`NO_GROUP` sentinel), grown on demand so
    /// `push` is O(1) instead of a linear scan over the groups.
    mn_to_group: Vec<u32>,
}

impl OpBatch {
    /// An empty batch.
    pub fn new() -> Self {
        Self::default()
    }

    fn push(&mut self, mn: usize, op: VerbOp) -> OpTag {
        if mn >= self.mn_to_group.len() {
            self.mn_to_group.resize(mn + 1, NO_GROUP);
        }
        let gi = match self.mn_to_group[mn] {
            NO_GROUP => {
                self.groups.push((mn, Vec::new()));
                let gi = self.groups.len() - 1;
                self.mn_to_group[mn] = gi as u32;
                gi
            }
            gi => gi as usize,
        };
        let ops = &mut self.groups[gi].1;
        ops.push(op);
        self.index.push((gi, ops.len() - 1));
        OpTag(self.index.len() - 1)
    }

    /// Plan a READ of `len` bytes at `addr` on `mn`.
    pub fn read(&mut self, mn: usize, addr: u64, len: usize) -> OpTag {
        self.push(
            mn,
            VerbOp::Read {
                addr,
                out: vec![0u8; len],
            },
        )
    }

    /// Plan a READ whose result buffer comes from `pool` instead of a
    /// fresh allocation (see [`BufPool`]). Identical to [`OpBatch::read`]
    /// in grouping, cost charges and result bytes.
    pub fn read_pooled(&mut self, mn: usize, addr: u64, len: usize, pool: &mut BufPool) -> OpTag {
        let out = pool.get(len);
        self.push(mn, VerbOp::Read { addr, out })
    }

    /// Plan a WRITE of `data` at `addr` on `mn`.
    pub fn write(&mut self, mn: usize, addr: u64, data: Vec<u8>) -> OpTag {
        self.push(mn, VerbOp::Write { addr, data })
    }

    /// Plan an 8B CAS at `addr` on `mn`.
    pub fn cas(&mut self, mn: usize, addr: u64, expect: u64, swap: u64) -> OpTag {
        self.push(
            mn,
            VerbOp::Cas {
                addr,
                expect,
                swap,
                old: 0,
            },
        )
    }

    /// Plan an 8B FAA at `addr` on `mn`.
    pub fn faa(&mut self, mn: usize, addr: u64, delta: u64) -> OpTag {
        self.push(
            mn,
            VerbOp::Faa {
                addr,
                delta,
                old: 0,
            },
        )
    }

    /// Total planned ops.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    /// Is the batch empty?
    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Number of per-MN doorbell groups (== doorbells `issue` will ring).
    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    /// The target MNs in issue order.
    pub fn mns(&self) -> impl Iterator<Item = usize> + '_ {
        self.groups.iter().map(|(mn, _)| *mn)
    }

    /// Ops planned against `mn`.
    pub fn group_len(&self, mn: usize) -> usize {
        self.groups
            .iter()
            .find(|(m, _)| *m == mn)
            .map(|(_, ops)| ops.len())
            .unwrap_or(0)
    }

    /// Issue every group as one synchronous doorbell batch (in first-use
    /// MN order); returns the completed batch for result harvesting.
    pub fn issue(
        mut self,
        ep: &Endpoint,
        mns: &[Arc<MemNode>],
        clk: &mut VClock,
    ) -> Result<BatchResult> {
        for (mn_id, ops) in self.groups.iter_mut() {
            ep.doorbell(&mns[*mn_id], ops, clk)?;
        }
        Ok(BatchResult {
            groups: self.groups,
            index: self.index,
        })
    }

    /// Fire-and-forget issue: charges the NICs but advances the caller's
    /// clock only by the CN issue cost (remote unlocks, log clears).
    /// Results are discarded.
    pub fn issue_async(
        mut self,
        ep: &Endpoint,
        mns: &[Arc<MemNode>],
        clk: &mut VClock,
    ) -> Result<()> {
        for (mn_id, ops) in self.groups.iter_mut() {
            ep.doorbell_async(&mns[*mn_id], ops, clk)?;
        }
        Ok(())
    }
}

/// Completed batch: resolves [`OpTag`]s to results.
#[derive(Debug)]
pub struct BatchResult {
    groups: Vec<(usize, Vec<VerbOp>)>,
    index: Vec<(usize, usize)>,
}

impl BatchResult {
    /// A completed result with no ops (an empty plan resumed for free).
    pub fn empty() -> Self {
        Self {
            groups: Vec::new(),
            index: Vec::new(),
        }
    }

    fn op(&self, tag: OpTag) -> &VerbOp {
        let (gi, oi) = self.index[tag.0];
        &self.groups[gi].1[oi]
    }

    /// Borrow the buffer a READ filled. Panics if `tag` is not a READ.
    pub fn read_buf(&self, tag: OpTag) -> &[u8] {
        match self.op(tag) {
            VerbOp::Read { out, .. } => out,
            other => panic!("OpTag does not name a READ: {other:?}"),
        }
    }

    /// Take ownership of a READ's buffer. Panics if `tag` is not a READ.
    pub fn take_read(&mut self, tag: OpTag) -> Vec<u8> {
        let (gi, oi) = self.index[tag.0];
        match &mut self.groups[gi].1[oi] {
            VerbOp::Read { out, .. } => std::mem::take(out),
            other => panic!("OpTag does not name a READ: {other:?}"),
        }
    }

    /// Return every remaining READ buffer's capacity to `pool` (buffers
    /// already moved out through [`BatchResult::take_read`] are skipped —
    /// the caller hands those back individually once parsed). Call after
    /// harvesting so the next ring's [`OpBatch::read_pooled`] plans reuse
    /// the capacity.
    pub fn recycle(self, pool: &mut BufPool) {
        for (_, ops) in self.groups {
            for op in ops {
                if let VerbOp::Read { out, .. } = op {
                    pool.put(out);
                }
            }
        }
    }

    /// The pre-op value a CAS or FAA observed. Panics on READ/WRITE tags.
    pub fn old(&self, tag: OpTag) -> u64 {
        match self.op(tag) {
            VerbOp::Cas { old, .. } | VerbOp::Faa { old, .. } => *old,
            other => panic!("OpTag does not name an atomic: {other:?}"),
        }
    }
}

/// The *merge* half of the plan/merge/split API: several frames' planned
/// [`OpBatch`]es coalesced into shared doorbells.
///
/// The pipelined coordinator works in three steps:
///
/// 1. **Plan** — each protocol phase builds an [`OpBatch`] describing the
///    one-sided ops it needs, *without* issuing it.
/// 2. **Merge** — the frame scheduler [`MergedBatch::absorb`]s the plans
///    of every frame that reached an issue point inside the same
///    coalescing window. Ops re-group per target MN across all absorbed
///    plans, so `n` plans touching one MN ring **one** doorbell instead
///    of `n`.
/// 3. **Split** — [`MergedBatch::issue_timed`] issues each per-MN group
///    once (completion-driven: per-op completion times, no shared clock),
///    and [`MergedResult::take`] hands each owning frame its own
///    [`BatchResult`] — resolvable by the frame's *original* [`OpTag`]s —
///    plus the completion time of the frame's slowest op. A frame's
///    virtual clock is charged only for its own ops, never for the other
///    plans that shared the doorbell.
///
/// ```
/// use std::sync::Arc;
/// use lotus::dm::{Endpoint, MemNode, MergedBatch, NetConfig, OpBatch, Rnic};
///
/// let mn = Arc::new(MemNode::new(0, 4096));
/// let region = mn.register(64).unwrap();
/// let ep = Endpoint::new(0, Arc::new(Rnic::new()), Arc::new(NetConfig::default()));
///
/// // Two frames plan independently...
/// let mut a = OpBatch::new();
/// let ta = a.write(0, region.base, 7u64.to_le_bytes().to_vec());
/// let mut b = OpBatch::new();
/// let tb = b.read(0, region.base, 8);
///
/// // ...the scheduler merges them into one doorbell...
/// let mut m = MergedBatch::new();
/// let sa = m.absorb(a);
/// let sb = m.absorb(b);
/// assert_eq!(m.n_doorbells(), 1, "two plans, one MN, one doorbell");
///
/// // ...and each frame gets its own results + completion time back.
/// let mut res = m.issue_timed(&ep, std::slice::from_ref(&mn), 0, |_| false).unwrap();
/// let (_ra, t_a, ok_a) = res.take(sa);
/// let (rb, t_b, ok_b) = res.take(sb);
/// assert_eq!(rb.read_buf(tb), &7u64.to_le_bytes()[..]);
/// assert!(t_a > 0 && t_b >= t_a);
/// assert!(ok_a && ok_b, "no injector installed: nothing faulted");
/// # let _ = ta;
/// ```
#[derive(Debug, Default)]
pub struct MergedBatch {
    /// The merged plan (per-MN grouping across all absorbed plans).
    inner: OpBatch,
    /// Per absorbed plan: original tag index -> merged tag index.
    slices: Vec<Vec<usize>>,
}

impl MergedBatch {
    /// An empty merged batch.
    pub fn new() -> Self {
        Self::default()
    }

    /// Absorb one frame's planned batch; returns the slice id used to
    /// [`MergedResult::take`] that frame's results back out. Ops keep
    /// their relative order within the plan and join the merged batch's
    /// per-MN groups.
    pub fn absorb(&mut self, plan: OpBatch) -> usize {
        let OpBatch { groups, index, .. } = plan;
        // Merged tag for each (src group, src op) position.
        let mut pos_map: Vec<Vec<usize>> = Vec::with_capacity(groups.len());
        for (mn, ops) in groups {
            let mut row = Vec::with_capacity(ops.len());
            for op in ops {
                row.push(self.inner.push(mn, op).0);
            }
            pos_map.push(row);
        }
        let remap = index.iter().map(|&(gi, oi)| pos_map[gi][oi]).collect();
        self.slices.push(remap);
        self.slices.len() - 1
    }

    /// Absorbed plan count.
    pub fn n_plans(&self) -> usize {
        self.slices.len()
    }

    /// Doorbells an issue will ring (one per distinct target MN) —
    /// strictly fewer than per-frame issue whenever two absorbed plans
    /// share an MN.
    pub fn n_doorbells(&self) -> usize {
        self.inner.n_groups()
    }

    /// Total merged ops.
    pub fn n_ops(&self) -> usize {
        self.inner.len()
    }

    /// Is there anything to issue?
    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    /// Issue every per-MN group as one completion-driven doorbell starting
    /// at virtual time `t_start` ([`Endpoint::doorbell_timed`]).
    ///
    /// `is_ride(mn)` lets the caller mark groups that extend a doorbell
    /// already rung to `mn` within the coalescing window (skips the
    /// per-doorbell overhead; see [`crate::txn::scheduler::Coalescer`]).
    /// The caller is responsible for gate-syncing before the issue.
    pub fn issue_timed<F: FnMut(usize) -> bool>(
        mut self,
        ep: &Endpoint,
        mns: &[Arc<MemNode>],
        t_start: u64,
        mut is_ride: F,
    ) -> Result<MergedResult> {
        let mut per_group: Vec<Vec<u64>> = Vec::with_capacity(self.inner.groups.len());
        let mut group_faulted: Vec<bool> = Vec::with_capacity(self.inner.groups.len());
        for (mn_id, ops) in self.inner.groups.iter_mut() {
            let ride = is_ride(*mn_id);
            let out = ep.doorbell_timed(&mns[*mn_id], ops, t_start, ride)?;
            per_group.push(out.done);
            group_faulted.push(out.faulted);
        }
        let completion = self
            .inner
            .index
            .iter()
            .map(|&(gi, oi)| per_group[gi][oi])
            .collect();
        Ok(MergedResult {
            groups: self.inner.groups,
            index: self.inner.index,
            completion,
            group_faulted,
            slices: self.slices,
        })
    }
}

/// The *split* half: a completed [`MergedBatch`], resolvable per owner.
#[derive(Debug)]
pub struct MergedResult {
    groups: Vec<(usize, Vec<VerbOp>)>,
    index: Vec<(usize, usize)>,
    /// Per merged tag: op completion time (MN done + return half-RTT).
    completion: Vec<u64>,
    /// Per group: did an injected doorbell fault hit the group's ring?
    group_faulted: Vec<bool>,
    slices: Vec<Vec<usize>>,
}

impl MergedResult {
    /// Extract one absorbed plan's results: a [`BatchResult`] addressed by
    /// the plan's **original** [`OpTag`]s, plus the completion time of the
    /// plan's slowest op (0 for an empty plan) — the only amount the
    /// owning frame's clock must be advanced by — and an `ok` flag that is
    /// false when any doorbell carrying the plan's ops was hit by an
    /// injected fault (the owner must treat the whole plan as timed out).
    /// Each slice can be taken once; taking it again yields an empty
    /// result.
    pub fn take(&mut self, slice: usize) -> (BatchResult, u64, bool) {
        let remap = std::mem::take(&mut self.slices[slice]);
        let mut ops = Vec::with_capacity(remap.len());
        let mut done = 0u64;
        let mut ok = true;
        for &m in &remap {
            let (gi, oi) = self.index[m];
            let op = std::mem::replace(
                &mut self.groups[gi].1[oi],
                VerbOp::Write {
                    addr: 0,
                    data: Vec::new(),
                },
            );
            done = done.max(self.completion[m]);
            ok &= !self.group_faulted[gi];
            ops.push(op);
        }
        let n = ops.len();
        (
            BatchResult {
                groups: vec![(0, ops)],
                index: (0..n).map(|i| (0, i)).collect(),
            },
            done,
            ok,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dm::netconfig::NetConfig;
    use crate::dm::rnic::Rnic;

    fn setup(n_mns: usize) -> (Vec<Arc<MemNode>>, Endpoint) {
        let mns = (0..n_mns)
            .map(|i| Arc::new(MemNode::new(i, 1 << 16)))
            .collect();
        let ep = Endpoint::new(0, Arc::new(Rnic::new()), Arc::new(NetConfig::default()));
        (mns, ep)
    }

    #[test]
    fn groups_ops_per_mn_in_first_use_order() {
        let (mns, _ep) = setup(3);
        let r0 = mns[0].register(64).unwrap();
        let r2 = mns[2].register(64).unwrap();
        let mut b = OpBatch::new();
        b.read(2, r2.base, 8);
        b.read(0, r0.base, 8);
        b.read(2, r2.base + 8, 8);
        b.read(0, r0.base + 8, 8);
        b.read(2, r2.base + 16, 8);
        assert_eq!(b.len(), 5);
        assert_eq!(b.n_groups(), 2, "two distinct MNs -> two doorbells");
        assert_eq!(b.mns().collect::<Vec<_>>(), vec![2, 0], "first-use order");
        assert_eq!(b.group_len(2), 3);
        assert_eq!(b.group_len(0), 2);
        assert_eq!(b.group_len(1), 0);
    }

    #[test]
    fn results_map_back_through_tags_across_groups() {
        let (mns, ep) = setup(2);
        let ra = mns[0].register(64).unwrap();
        let rb = mns[1].register(64).unwrap();
        mns[0].store_u64(ra.base, 0xAAAA).unwrap();
        mns[1].store_u64(rb.base, 0xBBBB).unwrap();
        let mut clk = VClock::zero();
        let mut b = OpBatch::new();
        // Interleave targets so tag order != group order.
        let t_b = b.read(1, rb.base, 8);
        let t_w = b.write(0, ra.base + 8, 0xCCCCu64.to_le_bytes().to_vec());
        let t_a = b.read(0, ra.base, 8);
        let t_cas = b.cas(1, rb.base + 8, 0, 42);
        let t_faa = b.faa(1, rb.base + 16, 5);
        let mut res = b.issue(&ep, &mns, &mut clk).unwrap();
        assert_eq!(res.read_buf(t_a), &0xAAAAu64.to_le_bytes()[..]);
        assert_eq!(res.take_read(t_b), 0xBBBBu64.to_le_bytes().to_vec());
        assert_eq!(res.old(t_cas), 0, "CAS on a fresh word sees 0");
        assert_eq!(res.old(t_faa), 0);
        assert_eq!(mns[1].load_u64(rb.base + 8).unwrap(), 42);
        assert_eq!(mns[1].load_u64(rb.base + 16).unwrap(), 5);
        assert_eq!(mns[0].load_u64(ra.base + 8).unwrap(), 0xCCCC);
        let _ = t_w;
    }

    #[test]
    fn one_doorbell_per_mn_beats_sequential_issues() {
        // 8 reads to one MN through OpBatch must cost ~one RTT, not eight.
        let (mns, ep) = setup(1);
        let r = mns[0].register(256).unwrap();
        let mut clk_batch = VClock::zero();
        let mut b = OpBatch::new();
        for i in 0..8u64 {
            b.read(0, r.base + i * 8, 8);
        }
        b.issue(&ep, &mns, &mut clk_batch).unwrap();

        let (mns2, ep2) = setup(1);
        let r2 = mns2[0].register(256).unwrap();
        let mut clk_seq = VClock::zero();
        for i in 0..8u64 {
            let mut single = OpBatch::new();
            single.read(0, r2.base + i * 8, 8);
            single.issue(&ep2, &mns2, &mut clk_seq).unwrap();
        }
        assert!(
            clk_batch.now() * 4 < clk_seq.now(),
            "batch {} vs sequential {}",
            clk_batch.now(),
            clk_seq.now()
        );
    }

    #[test]
    fn async_issue_advances_clock_by_issue_cost_only() {
        let (mns, ep) = setup(1);
        let r = mns[0].register(64).unwrap();
        let mut clk = VClock::zero();
        let mut b = OpBatch::new();
        b.write(0, r.base, 9u64.to_le_bytes().to_vec());
        b.issue_async(&ep, &mns, &mut clk).unwrap();
        assert!(
            clk.now() < ep.net.rtt_ns / 2,
            "fire-and-forget must not wait a round trip (t={})",
            clk.now()
        );
        // ...but the write really executed.
        assert_eq!(mns[0].load_u64(r.base).unwrap(), 9);
    }

    #[test]
    fn merged_plans_ring_strictly_fewer_doorbells_than_per_frame_issue() {
        // 3 frames, each planning 2 ops on each of 2 MNs. Per-frame issue
        // rings 3 x 2 = 6 doorbells; merged, the same 12 ops ring 2.
        let (mns, ep) = setup(2);
        let r0 = mns[0].register(256).unwrap();
        let r1 = mns[1].register(256).unwrap();
        let plan = |fi: u64| {
            let mut b = OpBatch::new();
            b.read(0, r0.base + fi * 16, 8);
            b.read(1, r1.base + fi * 16, 8);
            b.write(0, r0.base + fi * 16 + 8, fi.to_le_bytes().to_vec());
            b.write(1, r1.base + fi * 16 + 8, fi.to_le_bytes().to_vec());
            b
        };

        let rung_before = ep.nic.doorbells();
        let mut merged = MergedBatch::new();
        let mut per_frame_doorbells = 0;
        for fi in 0..3u64 {
            let p = plan(fi);
            per_frame_doorbells += p.n_groups();
            merged.absorb(p);
        }
        assert_eq!(per_frame_doorbells, 6);
        assert_eq!(merged.n_plans(), 3);
        assert_eq!(merged.n_ops(), 12);
        assert_eq!(merged.n_doorbells(), 2, "one doorbell per MN, not per frame");
        assert!(merged.n_doorbells() < per_frame_doorbells);

        let mut res = merged.issue_timed(&ep, &mns, 0, |_| false).unwrap();
        assert_eq!(
            ep.nic.doorbells() - rung_before,
            2,
            "the NIC saw exactly the merged doorbells"
        );
        for fi in (0..3usize).rev() {
            let (_r, done, ok) = res.take(fi);
            assert!(done >= ep.net.rtt_ns, "frame {fi} completion {done}");
            assert!(ok, "no injector: frame {fi} must not be faulted");
        }
        for fi in 0..3u64 {
            assert_eq!(mns[0].load_u64(r0.base + fi * 16 + 8).unwrap(), fi);
            assert_eq!(mns[1].load_u64(r1.base + fi * 16 + 8).unwrap(), fi);
        }
    }

    #[test]
    fn merged_results_route_back_to_owning_frames_by_original_tags() {
        let (mns, ep) = setup(2);
        let ra = mns[0].register(64).unwrap();
        let rb = mns[1].register(64).unwrap();
        mns[0].store_u64(ra.base, 111).unwrap();
        mns[1].store_u64(rb.base, 222).unwrap();

        // Frame A reads MN0 then MN1; frame B reads MN1 only.
        let mut a = OpBatch::new();
        let a0 = a.read(0, ra.base, 8);
        let a1 = a.read(1, rb.base, 8);
        let mut b = OpBatch::new();
        let b0 = b.read(1, rb.base, 8);

        let mut m = MergedBatch::new();
        let sa = m.absorb(a);
        let sb = m.absorb(b);
        let mut res = m.issue_timed(&ep, &mns, 0, |_| false).unwrap();
        let (mut res_b, done_b, _) = res.take(sb);
        let (mut res_a, done_a, _) = res.take(sa);
        assert_eq!(res_a.take_read(a0), 111u64.to_le_bytes().to_vec());
        assert_eq!(res_a.take_read(a1), 222u64.to_le_bytes().to_vec());
        assert_eq!(res_b.take_read(b0), 222u64.to_le_bytes().to_vec());
        assert!(done_a > 0 && done_b > 0);
    }

    #[test]
    fn completion_driven_issue_charges_each_frame_only_its_own_ops() {
        // Frame A has one cheap 8B read; frame B drags a large read
        // behind it on the same MN. A's completion must not include B's
        // service time beyond queueing ahead of it.
        let (mns, ep) = setup(1);
        let r = mns[0].register(1 << 14).unwrap();
        let mut a = OpBatch::new();
        a.read(0, r.base, 8);
        let mut b = OpBatch::new();
        b.read(0, r.base, 1 << 13); // ~8 KiB: >1170ns of byte cost
        let mut m = MergedBatch::new();
        let sa = m.absorb(a);
        let sb = m.absorb(b);
        let mut res = m.issue_timed(&ep, &mns, 0, |_| false).unwrap();
        let (_ra, done_a, _) = res.take(sa);
        let (_rb, done_b, _) = res.take(sb);
        assert!(
            done_a + 1000 < done_b,
            "A ({done_a}) must complete well before B ({done_b})"
        );
    }

    #[test]
    fn faulted_group_marks_only_its_owners_not_ok() {
        use crate::dm::faults::{FaultInjector, FaultRule, FaultsCell};
        // MN 0's ring is unreachable; MN 1 serves normally. Frame A rides
        // both MNs (not ok), frame B touches only MN 1 (ok).
        let (mns, ep) = setup(2);
        let cell = Arc::new(FaultsCell::new());
        cell.install(Some(Arc::new(
            FaultInjector::new(5).rule(FaultRule::mn_unreachable(0)),
        )));
        let ep = ep.with_faults(cell);
        let r0 = mns[0].register(64).unwrap();
        let r1 = mns[1].register(64).unwrap();
        let mut a = OpBatch::new();
        a.write(0, r0.base, 7u64.to_le_bytes().to_vec());
        a.read(1, r1.base, 8);
        let mut b = OpBatch::new();
        b.write(1, r1.base + 8, 8u64.to_le_bytes().to_vec());
        let mut m = MergedBatch::new();
        let sa = m.absorb(a);
        let sb = m.absorb(b);
        let mut res = m.issue_timed(&ep, &mns, 0, |_| false).unwrap();
        let (_ra, done_a, ok_a) = res.take(sa);
        let (_rb, _done_b, ok_b) = res.take(sb);
        assert!(!ok_a, "frame A's MN0 ring was unreachable");
        assert!(ok_b, "frame B never touched the faulted MN");
        assert!(
            done_a >= ep.doorbell_timeout_ns(),
            "faulted completions carry the timeout: {done_a}"
        );
        assert_eq!(mns[0].load_u64(r0.base).unwrap(), 0, "MN0 write lost");
        assert_eq!(mns[1].load_u64(r1.base + 8).unwrap(), 8, "MN1 write landed");
    }

    #[test]
    fn pooled_reads_recycle_capacity_across_rings_with_identical_results() {
        let (mns, ep) = setup(1);
        let r = mns[0].register(256).unwrap();
        for i in 0..8u64 {
            mns[0].store_u64(r.base + i * 8, 0x1000 + i).unwrap();
        }
        let mut pool = BufPool::new();

        // Ring 1: pool is empty — every READ allocates fresh.
        let mut clk_a = VClock::zero();
        let mut a = OpBatch::new();
        let tags_a: Vec<OpTag> = (0..8u64)
            .map(|i| a.read_pooled(0, r.base + i * 8, 8, &mut pool))
            .collect();
        assert_eq!(pool.reuses(), 0, "empty pool cannot serve a reuse");
        let mut res_a = a.issue(&ep, &mns, &mut clk_a).unwrap();
        for (i, &t) in tags_a.iter().enumerate() {
            assert_eq!(res_a.read_buf(t), &(0x1000 + i as u64).to_le_bytes()[..]);
        }
        // One buffer the caller keeps (take_read), the rest recycle.
        let kept = res_a.take_read(tags_a[0]);
        res_a.recycle(&mut pool);
        assert_eq!(pool.available(), 7, "7 of 8 buffers back on the free list");

        // Ring 2: the same plan shape reuses the recycled capacity —
        // same bytes, same virtual-time charge as ring 1.
        let mut clk_b = VClock::zero();
        let mut b = OpBatch::new();
        let tags_b: Vec<OpTag> = (0..8u64)
            .map(|i| b.read_pooled(0, r.base + i * 8, 8, &mut pool))
            .collect();
        assert_eq!(pool.reuses(), 7, "7 READs served from recycled buffers");
        assert_eq!(pool.available(), 0);
        let res_b = b.issue(&ep, &mns, &mut clk_b).unwrap();
        for (i, &t) in tags_b.iter().enumerate() {
            assert_eq!(res_b.read_buf(t), &(0x1000 + i as u64).to_le_bytes()[..]);
        }
        assert_eq!(clk_a.now(), clk_b.now(), "pooling never changes costs");
        // Buffers handed back individually (the parse-then-put idiom).
        pool.put(kept);
        res_b.recycle(&mut pool);
        assert_eq!(pool.available(), 9);
    }

    #[test]
    fn pooled_buffers_survive_the_merge_split_round_trip() {
        // A pooled plan absorbed into a MergedBatch comes back through
        // MergedResult::take with the same buffers; recycle reclaims them.
        let (mns, ep) = setup(1);
        let r = mns[0].register(64).unwrap();
        mns[0].store_u64(r.base, 77).unwrap();
        let mut pool = BufPool::new();
        pool.put(Vec::with_capacity(64));

        let mut plan = OpBatch::new();
        let tag = plan.read_pooled(0, r.base, 8, &mut pool);
        assert_eq!(pool.reuses(), 1, "served from the seeded buffer");
        let mut m = MergedBatch::new();
        let s = m.absorb(plan);
        let mut res = m.issue_timed(&ep, &mns, 0, |_| false).unwrap();
        let (br, done, ok) = res.take(s);
        assert!(ok && done > 0);
        assert_eq!(br.read_buf(tag), &77u64.to_le_bytes()[..]);
        br.recycle(&mut pool);
        assert_eq!(pool.available(), 1);
        assert!(
            pool.get(8).capacity() >= 64,
            "the seeded capacity round-tripped through merge/split"
        );
    }

    #[test]
    fn empty_batch_is_free() {
        let (mns, ep) = setup(1);
        let mut clk = VClock::zero();
        let res = OpBatch::new().issue(&ep, &mns, &mut clk).unwrap();
        assert_eq!(clk.now(), 0);
        drop(res);
        assert_eq!(OpBatch::new().len(), 0);
        assert!(OpBatch::new().is_empty());
    }
}
