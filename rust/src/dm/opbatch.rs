//! `OpBatch` — the shared one-sided doorbell-batch planner.
//!
//! Every multi-op exchange with the memory pool follows the same shape:
//! collect READ/WRITE/CAS/FAA verbs addressed at possibly-several MNs,
//! group them **per target MN**, issue each group as one doorbell batch
//! (one RTT + queued per-op service, paper §7.2), and map results back to
//! the logical operations that requested them. Before this module, that
//! plumbing was re-implemented ad hoc in every protocol phase of the
//! LOTUS coordinator *and* in every baseline; `OpBatch` is the single
//! implementation all of them plan through.
//!
//! Usage:
//!
//! ```
//! use std::sync::Arc;
//! use lotus::dm::{Endpoint, MemNode, NetConfig, OpBatch, Rnic, VClock};
//!
//! let mn = Arc::new(MemNode::new(0, 4096));
//! let region = mn.register(64).unwrap();
//! let ep = Endpoint::new(0, Arc::new(Rnic::new()), Arc::new(NetConfig::default()));
//! let mut clk = VClock::zero();
//!
//! let mut batch = OpBatch::new();
//! let w = batch.write(0, region.base, 7u64.to_le_bytes().to_vec());
//! let r = batch.read(0, region.base, 8);
//! let res = batch.issue(&ep, std::slice::from_ref(&mn), &mut clk).unwrap();
//! assert_eq!(res.read_buf(r), &7u64.to_le_bytes()[..]);
//! # let _ = w;
//! ```
//!
//! Guarantees relied on by the protocol code:
//!
//! - **Grouping**: ops targeting the same MN share one doorbell batch;
//!   groups are issued in first-use order of the MNs, and ops within a
//!   group stay in enqueue order. Cost charges are therefore *identical*
//!   to hand-built per-MN `VerbOp` vectors.
//! - **Tags**: each enqueue returns an [`OpTag`] naming the logical op;
//!   [`BatchResult`] resolves a tag to its buffer / old-value regardless
//!   of how the ops were grouped.
//! - **Async**: [`OpBatch::issue_async`] is the fire-and-forget variant
//!   (charges the NICs, advances the caller's clock only by the issue
//!   cost) used for unlock-style messages off the critical path.

use std::sync::Arc;

use crate::dm::clock::VClock;
use crate::dm::memnode::MemNode;
use crate::dm::verbs::{Endpoint, VerbOp};
use crate::Result;

/// Handle naming one enqueued op; resolves results in a [`BatchResult`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpTag(usize);

/// A planned set of one-sided ops, grouped per target MN.
#[derive(Debug, Default)]
pub struct OpBatch {
    /// Per-MN groups in first-use order: `(mn id, ops)`.
    groups: Vec<(usize, Vec<VerbOp>)>,
    /// tag index -> (group index, op index within the group).
    index: Vec<(usize, usize)>,
}

impl OpBatch {
    /// An empty batch.
    pub fn new() -> Self {
        Self::default()
    }

    fn push(&mut self, mn: usize, op: VerbOp) -> OpTag {
        let gi = match self.groups.iter().position(|(m, _)| *m == mn) {
            Some(gi) => gi,
            None => {
                self.groups.push((mn, Vec::new()));
                self.groups.len() - 1
            }
        };
        let ops = &mut self.groups[gi].1;
        ops.push(op);
        self.index.push((gi, ops.len() - 1));
        OpTag(self.index.len() - 1)
    }

    /// Plan a READ of `len` bytes at `addr` on `mn`.
    pub fn read(&mut self, mn: usize, addr: u64, len: usize) -> OpTag {
        self.push(
            mn,
            VerbOp::Read {
                addr,
                out: vec![0u8; len],
            },
        )
    }

    /// Plan a WRITE of `data` at `addr` on `mn`.
    pub fn write(&mut self, mn: usize, addr: u64, data: Vec<u8>) -> OpTag {
        self.push(mn, VerbOp::Write { addr, data })
    }

    /// Plan an 8B CAS at `addr` on `mn`.
    pub fn cas(&mut self, mn: usize, addr: u64, expect: u64, swap: u64) -> OpTag {
        self.push(
            mn,
            VerbOp::Cas {
                addr,
                expect,
                swap,
                old: 0,
            },
        )
    }

    /// Plan an 8B FAA at `addr` on `mn`.
    pub fn faa(&mut self, mn: usize, addr: u64, delta: u64) -> OpTag {
        self.push(
            mn,
            VerbOp::Faa {
                addr,
                delta,
                old: 0,
            },
        )
    }

    /// Total planned ops.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    /// Is the batch empty?
    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Number of per-MN doorbell groups (== doorbells `issue` will ring).
    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    /// The target MNs in issue order.
    pub fn mns(&self) -> impl Iterator<Item = usize> + '_ {
        self.groups.iter().map(|(mn, _)| *mn)
    }

    /// Ops planned against `mn`.
    pub fn group_len(&self, mn: usize) -> usize {
        self.groups
            .iter()
            .find(|(m, _)| *m == mn)
            .map(|(_, ops)| ops.len())
            .unwrap_or(0)
    }

    /// Issue every group as one synchronous doorbell batch (in first-use
    /// MN order); returns the completed batch for result harvesting.
    pub fn issue(
        mut self,
        ep: &Endpoint,
        mns: &[Arc<MemNode>],
        clk: &mut VClock,
    ) -> Result<BatchResult> {
        for (mn_id, ops) in self.groups.iter_mut() {
            ep.doorbell(&mns[*mn_id], ops, clk)?;
        }
        Ok(BatchResult {
            groups: self.groups,
            index: self.index,
        })
    }

    /// Fire-and-forget issue: charges the NICs but advances the caller's
    /// clock only by the CN issue cost (remote unlocks, log clears).
    /// Results are discarded.
    pub fn issue_async(
        mut self,
        ep: &Endpoint,
        mns: &[Arc<MemNode>],
        clk: &mut VClock,
    ) -> Result<()> {
        for (mn_id, ops) in self.groups.iter_mut() {
            ep.doorbell_async(&mns[*mn_id], ops, clk)?;
        }
        Ok(())
    }
}

/// Completed batch: resolves [`OpTag`]s to results.
#[derive(Debug)]
pub struct BatchResult {
    groups: Vec<(usize, Vec<VerbOp>)>,
    index: Vec<(usize, usize)>,
}

impl BatchResult {
    fn op(&self, tag: OpTag) -> &VerbOp {
        let (gi, oi) = self.index[tag.0];
        &self.groups[gi].1[oi]
    }

    /// Borrow the buffer a READ filled. Panics if `tag` is not a READ.
    pub fn read_buf(&self, tag: OpTag) -> &[u8] {
        match self.op(tag) {
            VerbOp::Read { out, .. } => out,
            other => panic!("OpTag does not name a READ: {other:?}"),
        }
    }

    /// Take ownership of a READ's buffer. Panics if `tag` is not a READ.
    pub fn take_read(&mut self, tag: OpTag) -> Vec<u8> {
        let (gi, oi) = self.index[tag.0];
        match &mut self.groups[gi].1[oi] {
            VerbOp::Read { out, .. } => std::mem::take(out),
            other => panic!("OpTag does not name a READ: {other:?}"),
        }
    }

    /// The pre-op value a CAS or FAA observed. Panics on READ/WRITE tags.
    pub fn old(&self, tag: OpTag) -> u64 {
        match self.op(tag) {
            VerbOp::Cas { old, .. } | VerbOp::Faa { old, .. } => *old,
            other => panic!("OpTag does not name an atomic: {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dm::netconfig::NetConfig;
    use crate::dm::rnic::Rnic;

    fn setup(n_mns: usize) -> (Vec<Arc<MemNode>>, Endpoint) {
        let mns = (0..n_mns)
            .map(|i| Arc::new(MemNode::new(i, 1 << 16)))
            .collect();
        let ep = Endpoint::new(0, Arc::new(Rnic::new()), Arc::new(NetConfig::default()));
        (mns, ep)
    }

    #[test]
    fn groups_ops_per_mn_in_first_use_order() {
        let (mns, _ep) = setup(3);
        let r0 = mns[0].register(64).unwrap();
        let r2 = mns[2].register(64).unwrap();
        let mut b = OpBatch::new();
        b.read(2, r2.base, 8);
        b.read(0, r0.base, 8);
        b.read(2, r2.base + 8, 8);
        b.read(0, r0.base + 8, 8);
        b.read(2, r2.base + 16, 8);
        assert_eq!(b.len(), 5);
        assert_eq!(b.n_groups(), 2, "two distinct MNs -> two doorbells");
        assert_eq!(b.mns().collect::<Vec<_>>(), vec![2, 0], "first-use order");
        assert_eq!(b.group_len(2), 3);
        assert_eq!(b.group_len(0), 2);
        assert_eq!(b.group_len(1), 0);
    }

    #[test]
    fn results_map_back_through_tags_across_groups() {
        let (mns, ep) = setup(2);
        let ra = mns[0].register(64).unwrap();
        let rb = mns[1].register(64).unwrap();
        mns[0].store_u64(ra.base, 0xAAAA).unwrap();
        mns[1].store_u64(rb.base, 0xBBBB).unwrap();
        let mut clk = VClock::zero();
        let mut b = OpBatch::new();
        // Interleave targets so tag order != group order.
        let t_b = b.read(1, rb.base, 8);
        let t_w = b.write(0, ra.base + 8, 0xCCCCu64.to_le_bytes().to_vec());
        let t_a = b.read(0, ra.base, 8);
        let t_cas = b.cas(1, rb.base + 8, 0, 42);
        let t_faa = b.faa(1, rb.base + 16, 5);
        let mut res = b.issue(&ep, &mns, &mut clk).unwrap();
        assert_eq!(res.read_buf(t_a), &0xAAAAu64.to_le_bytes()[..]);
        assert_eq!(res.take_read(t_b), 0xBBBBu64.to_le_bytes().to_vec());
        assert_eq!(res.old(t_cas), 0, "CAS on a fresh word sees 0");
        assert_eq!(res.old(t_faa), 0);
        assert_eq!(mns[1].load_u64(rb.base + 8).unwrap(), 42);
        assert_eq!(mns[1].load_u64(rb.base + 16).unwrap(), 5);
        assert_eq!(mns[0].load_u64(ra.base + 8).unwrap(), 0xCCCC);
        let _ = t_w;
    }

    #[test]
    fn one_doorbell_per_mn_beats_sequential_issues() {
        // 8 reads to one MN through OpBatch must cost ~one RTT, not eight.
        let (mns, ep) = setup(1);
        let r = mns[0].register(256).unwrap();
        let mut clk_batch = VClock::zero();
        let mut b = OpBatch::new();
        for i in 0..8u64 {
            b.read(0, r.base + i * 8, 8);
        }
        b.issue(&ep, &mns, &mut clk_batch).unwrap();

        let (mns2, ep2) = setup(1);
        let r2 = mns2[0].register(256).unwrap();
        let mut clk_seq = VClock::zero();
        for i in 0..8u64 {
            let mut single = OpBatch::new();
            single.read(0, r2.base + i * 8, 8);
            single.issue(&ep2, &mns2, &mut clk_seq).unwrap();
        }
        assert!(
            clk_batch.now() * 4 < clk_seq.now(),
            "batch {} vs sequential {}",
            clk_batch.now(),
            clk_seq.now()
        );
    }

    #[test]
    fn async_issue_advances_clock_by_issue_cost_only() {
        let (mns, ep) = setup(1);
        let r = mns[0].register(64).unwrap();
        let mut clk = VClock::zero();
        let mut b = OpBatch::new();
        b.write(0, r.base, 9u64.to_le_bytes().to_vec());
        b.issue_async(&ep, &mns, &mut clk).unwrap();
        assert!(
            clk.now() < ep.net.rtt_ns / 2,
            "fire-and-forget must not wait a round trip (t={})",
            clk.now()
        );
        // ...but the write really executed.
        assert_eq!(mns[0].load_u64(r.base).unwrap(), 9);
    }

    #[test]
    fn empty_batch_is_free() {
        let (mns, ep) = setup(1);
        let mut clk = VClock::zero();
        let res = OpBatch::new().issue(&ep, &mns, &mut clk).unwrap();
        assert_eq!(clk.now(), 0);
        drop(res);
        assert_eq!(OpBatch::new().len(), 0);
        assert!(OpBatch::new().is_empty());
    }
}
