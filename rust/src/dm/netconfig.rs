//! Calibrated cost model for the simulated RDMA fabric.
//!
//! Anchors (paper section 2.2 + DESIGN.md section 5):
//! - a single MN RNIC sustains ~35 Mops 8B WRITE => 28.6 ns/op service;
//! - the same RNIC sustains only ~2.5 Mops CAS   => 400 ns/op service;
//! - 56 Gbps line rate => 7 B/ns => ~0.143 ns/B serialization;
//! - one-sided verb RTT on ConnectX-3 IB ~= 2.0 us; UD RPC ~= 2.6 us.
//!
//! The knee these constants produce — 3 MNs saturating at a few dozen
//! concurrent CAS-locking transactions on SmallBank — is the calibration
//! anchor for reproducing fig. 2.

/// All cost-model constants, in integer nanoseconds (virtual time).
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// MN RNIC service time for an 8B-class READ (ns).
    pub read_svc_ns: u64,
    /// MN RNIC service time for an 8B-class WRITE (ns).
    pub write_svc_ns: u64,
    /// MN RNIC service time for CAS (ns) — the paper's 2.5 Mops ceiling.
    pub cas_svc_ns: u64,
    /// MN RNIC service time for FAA (ns).
    pub faa_svc_ns: u64,
    /// Serialization cost per payload byte (ns/B numerator over `bw_div`).
    pub per_byte_num: u64,
    /// Denominator for per-byte cost: cost = len * per_byte_num / bw_div.
    pub bw_div: u64,
    /// One-sided verb round-trip time (ns).
    pub rtt_ns: u64,
    /// CN->CN RPC round-trip time (UD QPs, ns).
    pub rpc_rtt_ns: u64,
    /// CN-side NIC per-request issue cost (DMA of one WQE, ns).
    pub cn_issue_ns: u64,
    /// CN-side NIC per-*doorbell* overhead (one PCIe MMIO ring, ns).
    /// Charged once per doorbell batch regardless of how many WQEs ride
    /// in it — the cost cross-transaction coalescing amortizes.
    pub doorbell_ns: u64,
    /// CN-side NIC per-*message* overhead of a CN-to-CN RPC SEND (one
    /// WQE post + doorbell on the UD QP, ns). Charged once per RPC
    /// message regardless of how many lock-class requests ride in it —
    /// the RPC-plane mirror of `doorbell_ns`, and the cost cross-lane
    /// RPC coalescing amortizes.
    pub rpc_send_ns: u64,
    /// Remote-CN CPU time to process one lock/unlock request in an RPC (ns).
    ///
    /// This is the **service time** of the destination's per-(CN, slot)
    /// handler queue ([`crate::dm::rpc::RpcFabric`]): a message of `n`
    /// requests occupies the handler for `n * rpc_handle_ns` after any
    /// queueing delay behind earlier arrivals. That queueing delay —
    /// arrival to service start — is measured per chunk and surfaced as
    /// `handler_wait_ns` on the destination CN's NIC and in
    /// [`crate::metrics::RunReport`]; it is the congestion signal the
    /// adaptive coalescing controller steers on.
    pub rpc_handle_ns: u64,
    /// Local CPU time for one lock-table CAS on the local CN (ns).
    pub local_lock_ns: u64,
    /// Timestamp-oracle access cost (scalable service in compute pool, ns).
    pub ts_oracle_ns: u64,
    /// CPU cost to process one transaction's application logic (ns).
    pub txn_logic_ns: u64,
    /// Local cache lookup/update cost (ns).
    pub cache_op_ns: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            read_svc_ns: 29,
            write_svc_ns: 29,
            cas_svc_ns: 400,
            faa_svc_ns: 400,
            per_byte_num: 143, // 0.143 ns/B == 143/1000
            bw_div: 1000,
            rtt_ns: 2_000,
            rpc_rtt_ns: 2_600,
            cn_issue_ns: 15,
            doorbell_ns: 40,
            rpc_send_ns: 40,
            rpc_handle_ns: 250,
            local_lock_ns: 30,
            ts_oracle_ns: 1_200,
            txn_logic_ns: 300,
            cache_op_ns: 25,
        }
    }
}

impl NetConfig {
    /// Serialization cost of a `len`-byte payload (ns).
    #[inline]
    pub fn byte_cost(&self, len: usize) -> u64 {
        (len as u64 * self.per_byte_num) / self.bw_div
    }

    /// MN-side service time of a READ of `len` bytes.
    #[inline]
    pub fn read_cost(&self, len: usize) -> u64 {
        self.read_svc_ns + self.byte_cost(len)
    }

    /// MN-side service time of a WRITE of `len` bytes.
    #[inline]
    pub fn write_cost(&self, len: usize) -> u64 {
        self.write_svc_ns + self.byte_cost(len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_anchors() {
        let c = NetConfig::default();
        // 35 Mops => ~28.6ns; we round to 29.
        assert!((28..=30).contains(&c.write_svc_ns));
        // 2.5 Mops => 400ns.
        assert_eq!(c.cas_svc_ns, 400);
        // CAS is much more expensive than WRITE (the paper's core premise).
        assert!(c.cas_svc_ns > 10 * c.write_svc_ns);
    }

    #[test]
    fn byte_cost_scales() {
        let c = NetConfig::default();
        assert_eq!(c.byte_cost(0), 0);
        // 1 KiB at 7 B/ns ~= 146 ns.
        let cost = c.byte_cost(1024);
        assert!((130..=160).contains(&cost), "cost={cost}");
        // Monotone.
        assert!(c.byte_cost(2048) > cost);
    }

    #[test]
    fn read_write_costs_include_base() {
        let c = NetConfig::default();
        assert_eq!(c.read_cost(0), c.read_svc_ns);
        assert!(c.write_cost(672) > c.write_svc_ns); // TPCC max record
    }
}
