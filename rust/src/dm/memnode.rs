//! Memory node: RDMA-registered memory regions + the MN-side RNIC.
//!
//! MN memory is a flat array of `AtomicU64` words addressed by *byte*
//! offsets (all allocations are 8B-aligned with 8B-rounded sizes, so no
//! two allocations share a word and plain Relaxed word ops are
//! race-free at the allocation level; intra-record consistency is
//! enforced by the seqlock cacheline versions in `store::record`).
//!
//! The MN CPU is used only at init (memory registration, metadata) — at
//! run time all access is one-sided through [`crate::dm::verbs`], exactly
//! as in the paper.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::dm::rnic::Rnic;
use crate::{Error, Result};

/// A contiguous RDMA-registered region [base, base+len) on some MN.
#[derive(Debug, Clone, Copy)]
pub struct MemRegion {
    /// Owning memory node id.
    pub mn: usize,
    /// Byte offset of the region start.
    pub base: u64,
    /// Region length in bytes.
    pub len: u64,
}

impl MemRegion {
    /// Does the region contain [addr, addr+len)?
    pub fn contains(&self, addr: u64, len: u64) -> bool {
        addr >= self.base && addr + len <= self.base + self.len
    }
}

/// One memory node.
pub struct MemNode {
    /// Node id.
    pub id: usize,
    words: Vec<AtomicU64>,
    /// The node's RNIC (the contended resource).
    pub rnic: Rnic,
    /// Bump pointer for region registration (init-time only).
    next: AtomicU64,
    /// Fail-stop flag (MNs are assumed fault-tolerant in the paper; this
    /// exists for fault-injection tests of the *replication* path).
    failed: std::sync::atomic::AtomicBool,
}

impl MemNode {
    /// Memory node with `capacity` bytes (rounded up to whole words).
    pub fn new(id: usize, capacity: u64) -> Self {
        let words = (capacity as usize).div_ceil(8);
        Self {
            id,
            words: (0..words).map(|_| AtomicU64::new(0)).collect(),
            rnic: Rnic::new(),
            next: AtomicU64::new(8), // offset 0 reserved as "null"
            failed: std::sync::atomic::AtomicBool::new(false),
        }
    }

    /// Total capacity in bytes.
    pub fn capacity(&self) -> u64 {
        self.words.len() as u64 * 8
    }

    /// Bytes allocated so far.
    pub fn allocated(&self) -> u64 {
        self.next.load(Ordering::Relaxed)
    }

    /// Register (allocate) a region of `len` bytes; 8B aligned + rounded.
    pub fn register(&self, len: u64) -> Result<MemRegion> {
        let len = crate::util::bytes::align_up(len.max(8), 8);
        let base = self.next.fetch_add(len, Ordering::Relaxed);
        if base + len > self.capacity() {
            return Err(Error::OutOfMemory(format!(
                "mn{}: want {} B at {:#x}, capacity {}",
                self.id,
                len,
                base,
                self.capacity()
            )));
        }
        Ok(MemRegion {
            mn: self.id,
            base,
            len,
        })
    }

    #[inline]
    fn word(&self, addr: u64) -> Result<&AtomicU64> {
        if addr % 8 != 0 {
            return Err(Error::BadAddress(addr, "unaligned"));
        }
        self.words
            .get((addr / 8) as usize)
            .ok_or(Error::BadAddress(addr, "out of range"))
    }

    /// Raw 8B load.
    #[inline]
    pub fn load_u64(&self, addr: u64) -> Result<u64> {
        Ok(self.word(addr)?.load(Ordering::Acquire))
    }

    /// Raw 8B store.
    #[inline]
    pub fn store_u64(&self, addr: u64, v: u64) -> Result<()> {
        self.word(addr)?.store(v, Ordering::Release);
        Ok(())
    }

    /// RDMA CAS semantics: atomically replace if equal; returns the old value.
    #[inline]
    pub fn cas_u64(&self, addr: u64, expect: u64, new: u64) -> Result<u64> {
        Ok(
            match self.word(addr)?.compare_exchange(
                expect,
                new,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(old) => old,
                Err(old) => old,
            },
        )
    }

    /// RDMA FAA semantics: fetch-and-add; returns the old value.
    #[inline]
    pub fn faa_u64(&self, addr: u64, delta: u64) -> Result<u64> {
        Ok(self.word(addr)?.fetch_add(delta, Ordering::AcqRel))
    }

    /// Copy `out.len()` bytes starting at `addr` (must be 8B aligned; the
    /// tail partial word is truncated from a whole-word load).
    pub fn read_bytes(&self, addr: u64, out: &mut [u8]) -> Result<()> {
        if addr % 8 != 0 {
            return Err(Error::BadAddress(addr, "unaligned read"));
        }
        let mut off = 0usize;
        while off < out.len() {
            let w = self.load_u64(addr + off as u64)?;
            let bytes = w.to_le_bytes();
            let n = (out.len() - off).min(8);
            out[off..off + n].copy_from_slice(&bytes[..n]);
            off += n;
        }
        Ok(())
    }

    /// Write `data` starting at `addr` (8B aligned; the tail partial word
    /// is read-modify-written so neighbours within the word survive).
    pub fn write_bytes(&self, addr: u64, data: &[u8]) -> Result<()> {
        if addr % 8 != 0 {
            return Err(Error::BadAddress(addr, "unaligned write"));
        }
        let mut off = 0usize;
        while off < data.len() {
            let n = (data.len() - off).min(8);
            let waddr = addr + off as u64;
            if n == 8 {
                let w = u64::from_le_bytes(data[off..off + 8].try_into().unwrap());
                self.store_u64(waddr, w)?;
            } else {
                let mut bytes = self.load_u64(waddr)?.to_le_bytes();
                bytes[..n].copy_from_slice(&data[off..off + n]);
                self.store_u64(waddr, u64::from_le_bytes(bytes))?;
            }
            off += n;
        }
        Ok(())
    }

    /// Inject / clear a fail-stop failure.
    pub fn set_failed(&self, failed: bool) {
        self.failed.store(failed, Ordering::SeqCst);
    }

    /// Is the node failed?
    pub fn is_failed(&self) -> bool {
        self.failed.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_is_aligned_and_disjoint() {
        let mn = MemNode::new(0, 1 << 16);
        let a = mn.register(13).unwrap();
        let b = mn.register(100).unwrap();
        assert_eq!(a.base % 8, 0);
        assert_eq!(b.base % 8, 0);
        assert!(a.base + a.len <= b.base, "regions overlap");
        assert_eq!(a.len, 16); // 13 rounded to 16
    }

    #[test]
    fn register_exhaustion() {
        let mn = MemNode::new(0, 64);
        assert!(mn.register(32).is_ok());
        assert!(mn.register(64).is_err());
    }

    #[test]
    fn u64_roundtrip_and_cas() {
        let mn = MemNode::new(0, 4096);
        let r = mn.register(64).unwrap();
        mn.store_u64(r.base, 7).unwrap();
        assert_eq!(mn.load_u64(r.base).unwrap(), 7);
        // CAS success
        assert_eq!(mn.cas_u64(r.base, 7, 9).unwrap(), 7);
        assert_eq!(mn.load_u64(r.base).unwrap(), 9);
        // CAS failure returns current
        assert_eq!(mn.cas_u64(r.base, 7, 11).unwrap(), 9);
        assert_eq!(mn.load_u64(r.base).unwrap(), 9);
    }

    #[test]
    fn faa_accumulates() {
        let mn = MemNode::new(0, 4096);
        let r = mn.register(8).unwrap();
        assert_eq!(mn.faa_u64(r.base, 5).unwrap(), 0);
        assert_eq!(mn.faa_u64(r.base, 3).unwrap(), 5);
        assert_eq!(mn.load_u64(r.base).unwrap(), 8);
    }

    #[test]
    fn byte_roundtrip_odd_lengths() {
        let mn = MemNode::new(0, 4096);
        let r = mn.register(64).unwrap();
        let data: Vec<u8> = (0..23).collect();
        mn.write_bytes(r.base, &data).unwrap();
        let mut out = vec![0u8; 23];
        mn.read_bytes(r.base, &mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn unaligned_access_rejected() {
        let mn = MemNode::new(0, 4096);
        assert!(mn.load_u64(3).is_err());
        assert!(mn.write_bytes(5, &[1, 2]).is_err());
    }

    #[test]
    fn out_of_range_rejected() {
        let mn = MemNode::new(0, 64);
        assert!(mn.load_u64(1 << 20).is_err());
    }

    #[test]
    fn prop_byte_roundtrip() {
        crate::testing::prop(40, |g| {
            let mn = MemNode::new(0, 1 << 14);
            let len = g.usize(1, 512);
            let r = mn.register(len as u64).unwrap();
            let data: Vec<u8> = (0..len).map(|_| g.u64(0, 255) as u8).collect();
            mn.write_bytes(r.base, &data).unwrap();
            let mut out = vec![0u8; len];
            mn.read_bytes(r.base, &mut out).unwrap();
            assert_eq!(out, data);
        });
    }
}
