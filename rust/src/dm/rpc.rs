//! CN-to-CN RPC fabric (UD-QP SEND/RECV with timeouts, paper section 3).
//!
//! In LOTUS remote lock/unlock requests travel CN-to-CN as RPCs handled by
//! the *i-th coordinator to i-th coordinator* pairing (paper 4.1), so each
//! (CN, slot) pair has its own handler queue — a CPU, not a NIC, since the
//! remote coordinator's CPU executes the lock ops. The actual lock-table
//! mutation is performed by the caller thread against the target CN's
//! (real, shared) lock table after the cost is charged; this is
//! functionally identical to a synchronous RPC and keeps the simulator
//! single-address-space.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::dm::clock::VClock;
use crate::dm::netconfig::NetConfig;
use crate::dm::rnic::Rnic;
use crate::{Error, Result};

/// RPC fabric across CNs.
pub struct RpcFabric {
    /// Per-CN NIC (shared with one-sided verbs from that CN).
    cn_nics: Vec<Arc<Rnic>>,
    /// Per-(CN, coordinator-slot) handler CPU queues.
    handlers: Vec<Vec<Arc<Rnic>>>,
    /// Fail-stop flags per CN.
    failed: Vec<AtomicBool>,
    net: Arc<NetConfig>,
}

impl RpcFabric {
    /// Fabric for `n_cns` CNs with `slots` coordinator slots each.
    pub fn new(cn_nics: Vec<Arc<Rnic>>, slots: usize, net: Arc<NetConfig>) -> Self {
        let n = cn_nics.len();
        Self {
            cn_nics,
            handlers: (0..n)
                .map(|_| (0..slots).map(|_| Arc::new(Rnic::new())).collect())
                .collect(),
            failed: (0..n).map(|_| AtomicBool::new(false)).collect(),
            net,
        }
    }

    /// Number of CNs.
    pub fn n_cns(&self) -> usize {
        self.cn_nics.len()
    }

    /// Inject / clear a CN fail-stop failure.
    pub fn set_failed(&self, cn: usize, failed: bool) {
        self.failed[cn].store(failed, Ordering::SeqCst);
    }

    /// Is the CN failed?
    pub fn is_failed(&self, cn: usize) -> bool {
        self.failed[cn].load(Ordering::SeqCst)
    }

    /// Charge a synchronous RPC carrying `n_reqs` lock-class requests from
    /// `(src_cn)` to `(dst_cn, slot)`; advances `clk` to the reply time.
    /// Fails with `NodeUnavailable` (after a timeout charge) if the target
    /// CN is failed — the UD transport's timeout mechanism.
    pub fn call(
        &self,
        src_cn: usize,
        dst_cn: usize,
        slot: usize,
        n_reqs: usize,
        clk: &mut VClock,
    ) -> Result<()> {
        if self.is_failed(dst_cn) {
            // Timeout: the caller burns a full timeout interval.
            clk.advance(self.net.rpc_rtt_ns * 4);
            return Err(Error::NodeUnavailable(format!("cn{dst_cn} (rpc timeout)")));
        }
        let t_send = self.cn_nics[src_cn].charge(clk.now(), self.net.cn_issue_ns);
        let t_arrive = t_send + self.net.rpc_rtt_ns / 2;
        // Receive-side NIC + handler CPU (batched requests in ONE message,
        // paper 4.1: "multiple remote lock requests ... batched into a
        // single RDMA message, saving IOPS").
        let t_recv = self.cn_nics[dst_cn].charge(t_arrive, self.net.cn_issue_ns);
        let t_handled = self.handlers[dst_cn][slot]
            .charge(t_recv, self.net.rpc_handle_ns * n_reqs.max(1) as u64);
        clk.catch_up(t_handled + self.net.rpc_rtt_ns / 2);
        Ok(())
    }

    /// Fire-and-forget RPC (async unlock): charges queues, caller clock
    /// advances only by the send cost.
    pub fn call_async(
        &self,
        src_cn: usize,
        dst_cn: usize,
        slot: usize,
        n_reqs: usize,
        clk: &mut VClock,
    ) -> Result<()> {
        if self.is_failed(dst_cn) {
            return Err(Error::NodeUnavailable(format!("cn{dst_cn} (async rpc)")));
        }
        let t_send = self.cn_nics[src_cn].charge(clk.now(), self.net.cn_issue_ns);
        let t_arrive = t_send + self.net.rpc_rtt_ns / 2;
        let t_recv = self.cn_nics[dst_cn].charge(t_arrive, self.net.cn_issue_ns);
        self.handlers[dst_cn][slot].charge(t_recv, self.net.rpc_handle_ns * n_reqs.max(1) as u64);
        clk.catch_up(t_send);
        Ok(())
    }

    /// Handler-CPU busy time of a CN (for the ablation's CPU-saturation
    /// effect on read-heavy workloads, fig. 14 TATP).
    pub fn handler_busy_ns(&self, cn: usize) -> u64 {
        self.handlers[cn].iter().map(|h| h.busy_ns()).sum()
    }

    /// Reset every handler queue to idle (between benchmark runs).
    pub fn reset_queues(&self) {
        for cn in &self.handlers {
            for h in cn {
                h.reset();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric(n: usize, slots: usize) -> RpcFabric {
        let nics = (0..n).map(|_| Arc::new(Rnic::new())).collect();
        RpcFabric::new(nics, slots, Arc::new(NetConfig::default()))
    }

    #[test]
    fn rpc_costs_at_least_one_rtt() {
        let f = fabric(2, 1);
        let mut clk = VClock::zero();
        f.call(0, 1, 0, 1, &mut clk).unwrap();
        assert!(clk.now() >= f.net.rpc_rtt_ns, "t={}", clk.now());
    }

    #[test]
    fn batched_requests_cheaper_than_separate_calls() {
        let f1 = fabric(2, 1);
        let mut c1 = VClock::zero();
        f1.call(0, 1, 0, 8, &mut c1).unwrap();

        let f2 = fabric(2, 1);
        let mut c2 = VClock::zero();
        for _ in 0..8 {
            f2.call(0, 1, 0, 1, &mut c2).unwrap();
        }
        assert!(c1.now() * 3 < c2.now(), "batch {} vs {}", c1.now(), c2.now());
    }

    #[test]
    fn failed_cn_times_out() {
        let f = fabric(2, 1);
        f.set_failed(1, true);
        let mut clk = VClock::zero();
        let err = f.call(0, 1, 0, 1, &mut clk).unwrap_err();
        assert!(matches!(err, Error::NodeUnavailable(_)));
        assert!(clk.now() >= f.net.rpc_rtt_ns * 4, "timeout not charged");
        f.set_failed(1, false);
        f.call(0, 1, 0, 1, &mut VClock::zero()).unwrap();
    }

    #[test]
    fn async_call_does_not_block() {
        let f = fabric(2, 1);
        let mut clk = VClock::zero();
        f.call_async(0, 1, 0, 4, &mut clk).unwrap();
        assert!(clk.now() < f.net.rpc_rtt_ns / 2);
        assert!(f.handler_busy_ns(1) > 0);
    }

    #[test]
    fn handler_queues_are_per_slot() {
        let f = fabric(2, 2);
        let mut c0 = VClock::zero();
        let mut c1 = VClock::zero();
        // Two slots handled in parallel: same arrival, no cross-queueing.
        f.call(0, 1, 0, 10, &mut c0).unwrap();
        f.call(0, 1, 1, 10, &mut c1).unwrap();
        // c1 may still pay NIC serialization, but not slot-0's handler time.
        let serial = f.net.rpc_handle_ns * 10;
        assert!(c1.now() < c0.now() + serial, "slots share a queue?");
    }
}
