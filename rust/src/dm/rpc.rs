//! CN-to-CN RPC fabric (UD-QP SEND/RECV with timeouts, paper section 3).
//!
//! In LOTUS remote lock/unlock requests travel CN-to-CN as RPCs handled by
//! the *i-th coordinator to i-th coordinator* pairing (paper 4.1), so each
//! (CN, slot) pair has its own handler queue — a CPU, not a NIC, since the
//! remote coordinator's CPU executes the lock ops. The actual lock-table
//! mutation is performed by the caller thread against the target CN's
//! (real, shared) lock table after the cost is charged; this is
//! functionally identical to a synchronous RPC and keeps the simulator
//! single-address-space.
//!
//! # Split-phase surface (ISSUE 5)
//!
//! The fabric mirrors [`crate::dm::verbs::Endpoint`]'s split between a
//! blocking doorbell and the completion-driven `doorbell_timed`:
//!
//! - [`RpcFabric::call`] / [`RpcFabric::call_async`] are the blocking /
//!   fire-and-forget single-owner forms (sequential conduits, baselines,
//!   recovery, resharding).
//! - [`RpcFabric::send_timed`] is the completion-driven primitive the
//!   pipelined scheduler's RPC-plane coalescing builds on: **one** RPC
//!   message from `src_cn` to `(dst_cn, slot)` carrying several owners'
//!   lock batches, fired at an explicit virtual time, returning *per
//!   owner* completion times. Each owner's clock advances only to the
//!   handler completing its own chunk — never to the whole message.
//! - [`RpcFabric::send_async_at`] is the fire-and-forget mirror at an
//!   explicit time (stale parked unlock plans flushing out).
//!
//! Every message charges `rpc_send_ns` (the per-message WQE+doorbell
//! overhead on the UD QP) exactly once — the cost cross-lane coalescing
//! amortizes — and counts on the source CN's [`Rnic`]
//! (`rpc_messages`/`rpc_reqs`); requests that ride a message another
//! lane paid for are `coalesced_rpc_reqs`.
//!
//! # Handler queueing model (ISSUE 6)
//!
//! Each handler queue is an exact FIFO server ([`Rnic::charge`]) at
//! `rpc_handle_ns` per lock-class request, so the fabric measures true
//! *queueing delay* per handled chunk — virtual ns between a chunk's
//! arrival at its `(dst CN, slot)` queue and its service start. The delay
//! is attributed to the **destination** CN's NIC counters
//! (`handler_wait_ns`/`handler_chunks`, the CN whose handler CPU is the
//! bottleneck), accumulated per destination on the fabric itself, and
//! folded into a fabric-wide [`Histogram`] for p99 reporting. A live
//! backlog probe ([`RpcFabric::handler_backlog_ns`]) exposes the same
//! signal *before* sending — what the adaptive coalescing controller
//! steers on.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::dm::clock::VClock;
use crate::dm::faults::{FaultAction, FaultInjector};
use crate::dm::netconfig::NetConfig;
use crate::dm::rnic::Rnic;
use crate::metrics::Histogram;
use crate::{Error, Result};

/// RPC fabric across CNs.
pub struct RpcFabric {
    /// Per-CN NIC (shared with one-sided verbs from that CN).
    cn_nics: Vec<Arc<Rnic>>,
    /// Per-(CN, coordinator-slot) handler CPU queues.
    handlers: Vec<Vec<Arc<Rnic>>>,
    /// Fail-stop flags per CN.
    failed: Vec<AtomicBool>,
    /// Optional deterministic fault injector, consulted once per message.
    /// `None` (the default) is byte-inert: no fault path is evaluated.
    faults: RwLock<Option<Arc<FaultInjector>>>,
    /// Cumulative handler-queue wait per *destination* CN (virtual ns).
    dst_wait_ns: Vec<AtomicU64>,
    /// Handled chunks that wait was measured over, per destination CN.
    dst_chunks: Vec<AtomicU64>,
    /// Fabric-wide distribution of per-chunk handler waits (for p99).
    wait_hist: Histogram,
    net: Arc<NetConfig>,
}

impl RpcFabric {
    /// Fabric for `n_cns` CNs with `slots` coordinator slots each.
    pub fn new(cn_nics: Vec<Arc<Rnic>>, slots: usize, net: Arc<NetConfig>) -> Self {
        let n = cn_nics.len();
        Self {
            cn_nics,
            handlers: (0..n)
                .map(|_| (0..slots).map(|_| Arc::new(Rnic::new())).collect())
                .collect(),
            failed: (0..n).map(|_| AtomicBool::new(false)).collect(),
            faults: RwLock::new(None),
            dst_wait_ns: (0..n).map(|_| AtomicU64::new(0)).collect(),
            dst_chunks: (0..n).map(|_| AtomicU64::new(0)).collect(),
            wait_hist: Histogram::new(),
            net,
        }
    }

    /// Number of CNs.
    pub fn n_cns(&self) -> usize {
        self.cn_nics.len()
    }

    /// Inject / clear a CN fail-stop failure.
    pub fn set_failed(&self, cn: usize, failed: bool) {
        self.failed[cn].store(failed, Ordering::SeqCst);
    }

    /// Is the CN failed?
    pub fn is_failed(&self, cn: usize) -> bool {
        self.failed[cn].load(Ordering::SeqCst)
    }

    /// Install (or clear, with `None`) the deterministic fault injector.
    pub fn set_faults(&self, faults: Option<Arc<FaultInjector>>) {
        *self.faults.write().unwrap() = faults;
    }

    /// The injector's verdict for one message ([`FaultAction::Deliver`]
    /// when none is installed).
    fn fault_action(
        &self,
        src_cn: usize,
        dst_cn: usize,
        slot: usize,
        t_send: u64,
        n_reqs: u64,
    ) -> FaultAction {
        match self.faults.read().unwrap().as_ref() {
            Some(f) => f.decide(src_cn, dst_cn, slot, t_send, n_reqs),
            None => FaultAction::Deliver,
        }
    }

    /// The UD transport's timeout interval: what a caller burns before
    /// declaring the target CN unavailable.
    pub fn timeout_ns(&self) -> u64 {
        self.net.rpc_rtt_ns * 4
    }

    /// The single owner of the unreachable-CN timeout contract, shared by
    /// both planes: a synchronous message that is never answered (failed
    /// destination, or a lost SEND) costs the caller one full timeout
    /// interval before `NodeUnavailable` surfaces.
    ///
    /// Direct plane: [`RpcFabric::charge_timeout`] burns the interval on
    /// a live clock. Staged plane: [`RpcFabric::timeout_done`] maps the
    /// post time to the virtual instant the timeout fires (the caller
    /// owns the charge — see [`crate::txn::scheduler`]'s RPC ring).
    pub fn timeout_done(&self, t_post: u64) -> u64 {
        t_post + self.timeout_ns()
    }

    /// Burn one timeout interval on `clk` and produce the
    /// `NodeUnavailable` error the caller surfaces.
    pub fn charge_timeout(&self, clk: &mut VClock, dst_cn: usize) -> Error {
        clk.advance(self.timeout_ns());
        Error::NodeUnavailable(format!("cn{dst_cn} (rpc timeout)"))
    }

    /// Charge a synchronous RPC carrying `n_reqs` lock-class requests from
    /// `(src_cn)` to `(dst_cn, slot)`; advances `clk` to the reply time.
    /// Fails with `NodeUnavailable` (after a timeout charge) if the target
    /// CN is failed — the UD transport's timeout mechanism.
    pub fn call(
        &self,
        src_cn: usize,
        dst_cn: usize,
        slot: usize,
        n_reqs: usize,
        clk: &mut VClock,
    ) -> Result<()> {
        if self.is_failed(dst_cn) {
            // Timeout: the caller burns a full timeout interval.
            return Err(self.charge_timeout(clk, dst_cn));
        }
        match self.send_timed(src_cn, dst_cn, slot, &[n_reqs], clk.now()) {
            Ok(done) => {
                clk.catch_up(done[0]);
                Ok(())
            }
            // A lost or unanswerable message is detected the same way a
            // failed CN is: by burning the timeout interval.
            Err(_) => Err(self.charge_timeout(clk, dst_cn)),
        }
    }

    /// Split-phase send: **one** RPC message from `src_cn` to
    /// `(dst_cn, slot)` carrying every owner's lock batch (`owners[i]`
    /// requests for owner `i`, in post order — parked riders first),
    /// fired at virtual time `t_send`. Returns each owner's completion
    /// time: the handler CPU serves the chunks in order, and an owner's
    /// reply lands a half-RTT after *its* chunk completes (batched
    /// requests in ONE message, paper 4.1: "multiple remote lock requests
    /// ... batched into a single RDMA message, saving IOPS").
    ///
    /// Counts one `rpc_message` (with the total request count) on the
    /// source CN NIC; the caller accounts coalesced riders. Fails without
    /// charging if the target CN is failed — the caller owns the timeout
    /// charge (see [`RpcFabric::timeout_ns`]).
    pub fn send_timed(
        &self,
        src_cn: usize,
        dst_cn: usize,
        slot: usize,
        owners: &[usize],
        t_send: u64,
    ) -> Result<Vec<u64>> {
        if self.is_failed(dst_cn) {
            return Err(Error::NodeUnavailable(format!("cn{dst_cn} (rpc timeout)")));
        }
        let total: u64 = owners.iter().map(|&n| n.max(1) as u64).sum();
        let act = self.fault_action(src_cn, dst_cn, slot, t_send, total);
        if act == FaultAction::Drop {
            // The SEND is lost in the fabric: like the failed-CN path the
            // caller owns the timeout charge; the loss itself is counted.
            self.cn_nics[src_cn].note_rpc_dropped();
            return Err(Error::NodeUnavailable(format!("cn{dst_cn} (rpc lost)")));
        }
        self.cn_nics[src_cn].note_rpc_message(total);
        // One SEND WQE + doorbell per message, however many requests ride.
        let t_sent = self.cn_nics[src_cn]
            .charge(t_send, self.net.rpc_send_ns + self.net.cn_issue_ns);
        let mut t_arrive = t_sent + self.net.rpc_rtt_ns / 2;
        if let FaultAction::Delay(d) = act {
            t_arrive += d;
        }
        let slow = match act {
            FaultAction::Slow(m) => m.max(1),
            _ => 1,
        };
        let mut t = self.cn_nics[dst_cn].charge(t_arrive, self.net.cn_issue_ns);
        let mut out = Vec::with_capacity(owners.len());
        for &n in owners {
            let svc = self.net.rpc_handle_ns * n.max(1) as u64 * slow;
            let done = self.handlers[dst_cn][slot].charge(t, svc);
            // Exact queueing delay: arrival -> service start. charge()
            // completes at max(arrival, busy) + svc, so the wait falls
            // straight out of the completion time.
            self.note_handler_wait(dst_cn, done - svc - t);
            t = done;
            out.push(t + self.net.rpc_rtt_ns / 2);
        }
        Ok(out)
    }

    /// Fire-and-forget message at an explicit virtual time (the
    /// split-phase mirror of [`RpcFabric::call_async`], used to flush
    /// stale parked unlock plans): charges the queues, returns the
    /// send-complete time — the only amount a caller's clock may advance.
    pub fn send_async_at(
        &self,
        src_cn: usize,
        dst_cn: usize,
        slot: usize,
        n_reqs: usize,
        t_send: u64,
    ) -> Result<u64> {
        if self.is_failed(dst_cn) {
            return Err(Error::NodeUnavailable(format!("cn{dst_cn} (async rpc)")));
        }
        let act = self.fault_action(src_cn, dst_cn, slot, t_send, n_reqs.max(1) as u64);
        self.cn_nics[src_cn].note_rpc_message(n_reqs.max(1) as u64);
        let t_sent = self.cn_nics[src_cn]
            .charge(t_send, self.net.rpc_send_ns + self.net.cn_issue_ns);
        if act == FaultAction::Drop {
            // Fire-and-forget: the send was paid for, then the message
            // silently vanished — nothing arrives at the destination.
            self.cn_nics[src_cn].note_rpc_dropped();
            return Ok(t_sent);
        }
        let mut t_arrive = t_sent + self.net.rpc_rtt_ns / 2;
        if let FaultAction::Delay(d) = act {
            t_arrive += d;
        }
        let slow = match act {
            FaultAction::Slow(m) => m.max(1),
            _ => 1,
        };
        let t_recv = self.cn_nics[dst_cn].charge(t_arrive, self.net.cn_issue_ns);
        let svc = self.net.rpc_handle_ns * n_reqs.max(1) as u64 * slow;
        let done = self.handlers[dst_cn][slot].charge(t_recv, svc);
        self.note_handler_wait(dst_cn, done - svc - t_recv);
        Ok(t_sent)
    }

    /// Fire-and-forget RPC (async unlock): charges queues, caller clock
    /// advances only by the send cost.
    pub fn call_async(
        &self,
        src_cn: usize,
        dst_cn: usize,
        slot: usize,
        n_reqs: usize,
        clk: &mut VClock,
    ) -> Result<()> {
        let t_sent = self.send_async_at(src_cn, dst_cn, slot, n_reqs, clk.now())?;
        clk.catch_up(t_sent);
        Ok(())
    }

    /// Handler-CPU busy time of a CN (for the ablation's CPU-saturation
    /// effect on read-heavy workloads, fig. 14 TATP).
    pub fn handler_busy_ns(&self, cn: usize) -> u64 {
        self.handlers[cn].iter().map(|h| h.busy_ns()).sum()
    }

    /// Attribute one handled chunk's queueing delay to its destination.
    fn note_handler_wait(&self, dst_cn: usize, wait_ns: u64) {
        self.cn_nics[dst_cn].note_handler_wait(wait_ns);
        self.dst_wait_ns[dst_cn].fetch_add(wait_ns, Ordering::Relaxed);
        self.dst_chunks[dst_cn].fetch_add(1, Ordering::Relaxed);
        self.wait_hist.record(wait_ns);
    }

    /// Cumulative handler-queue wait of chunks handled *at* `cn` (virtual ns).
    pub fn handler_wait_ns(&self, cn: usize) -> u64 {
        self.dst_wait_ns[cn].load(Ordering::Relaxed)
    }

    /// Chunks handled at `cn` that wait was measured over.
    pub fn handler_chunks(&self, cn: usize) -> u64 {
        self.dst_chunks[cn].load(Ordering::Relaxed)
    }

    /// Mean handler-queue wait at destination `cn` (0 if nothing handled).
    pub fn mean_handler_wait_ns(&self, cn: usize) -> f64 {
        let n = self.handler_chunks(cn);
        if n == 0 {
            0.0
        } else {
            self.handler_wait_ns(cn) as f64 / n as f64
        }
    }

    /// 99th percentile of per-chunk handler-queue wait, fabric-wide (ns).
    pub fn handler_wait_p99_ns(&self) -> u64 {
        self.wait_hist.p99()
    }

    /// Live backlog probe for a message that would be sent at `t_send`:
    /// virtual ns the `(dst_cn, slot)` handler queue is booked beyond the
    /// message's estimated arrival (ignoring source-NIC queueing — the
    /// probe must not depend on the sender's own load). 0 when the queue
    /// will have drained by then. This is the pre-send congestion signal
    /// the adaptive coalescing controller steers on.
    pub fn handler_backlog_ns(&self, dst_cn: usize, slot: usize, t_send: u64) -> u64 {
        let t_arrive = t_send
            + self.net.rpc_send_ns
            + self.net.cn_issue_ns
            + self.net.rpc_rtt_ns / 2
            + self.net.cn_issue_ns;
        self.handlers[dst_cn][slot]
            .busy_until()
            .saturating_sub(t_arrive)
    }

    /// Reset every per-destination queue to idle (between benchmark runs):
    /// handler busy time AND the wait accounting (per-destination sums,
    /// chunk counts, and the fabric-wide wait histogram).
    pub fn reset_queues(&self) {
        for cn in &self.handlers {
            for h in cn {
                h.reset();
            }
        }
        for w in &self.dst_wait_ns {
            w.store(0, Ordering::Relaxed);
        }
        for c in &self.dst_chunks {
            c.store(0, Ordering::Relaxed);
        }
        self.wait_hist.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric(n: usize, slots: usize) -> RpcFabric {
        let nics = (0..n).map(|_| Arc::new(Rnic::new())).collect();
        RpcFabric::new(nics, slots, Arc::new(NetConfig::default()))
    }

    #[test]
    fn rpc_costs_at_least_one_rtt() {
        let f = fabric(2, 1);
        let mut clk = VClock::zero();
        f.call(0, 1, 0, 1, &mut clk).unwrap();
        assert!(clk.now() >= f.net.rpc_rtt_ns, "t={}", clk.now());
    }

    #[test]
    fn batched_requests_cheaper_than_separate_calls() {
        let f1 = fabric(2, 1);
        let mut c1 = VClock::zero();
        f1.call(0, 1, 0, 8, &mut c1).unwrap();

        let f2 = fabric(2, 1);
        let mut c2 = VClock::zero();
        for _ in 0..8 {
            f2.call(0, 1, 0, 1, &mut c2).unwrap();
        }
        assert!(c1.now() * 3 < c2.now(), "batch {} vs {}", c1.now(), c2.now());
    }

    #[test]
    fn failed_cn_times_out() {
        let f = fabric(2, 1);
        f.set_failed(1, true);
        let mut clk = VClock::zero();
        let err = f.call(0, 1, 0, 1, &mut clk).unwrap_err();
        assert!(matches!(err, Error::NodeUnavailable(_)));
        assert!(clk.now() >= f.net.rpc_rtt_ns * 4, "timeout not charged");
        f.set_failed(1, false);
        f.call(0, 1, 0, 1, &mut VClock::zero()).unwrap();
    }

    #[test]
    fn async_call_does_not_block() {
        let f = fabric(2, 1);
        let mut clk = VClock::zero();
        f.call_async(0, 1, 0, 4, &mut clk).unwrap();
        assert!(clk.now() < f.net.rpc_rtt_ns / 2);
        assert!(f.handler_busy_ns(1) > 0);
    }

    #[test]
    fn merged_send_is_one_message_with_per_owner_completions() {
        // Two owners' batches in one message: one rpc_send_ns charge, the
        // handler serves the chunks in order, and each owner's completion
        // reflects only its own chunk's place in the queue.
        let f = fabric(2, 1);
        let times = f.send_timed(0, 1, 0, &[3, 2], 1_000).unwrap();
        assert_eq!(times.len(), 2);
        assert!(times[0] < times[1], "chunks serve in post order");
        assert!(times[0] >= 1_000 + f.net.rpc_rtt_ns, "at least one RTT");
        assert_eq!(
            times[1] - times[0],
            f.net.rpc_handle_ns * 2,
            "the later owner waits exactly its own handler time"
        );
        assert_eq!(f.cn_nics[0].rpc_messages(), 1, "ONE message for both");
        assert_eq!(f.cn_nics[0].rpc_reqs(), 5);

        // The same five requests as two separate calls cost two messages
        // and strictly more virtual time for the later caller.
        let g = fabric(2, 1);
        let a = g.send_timed(0, 1, 0, &[3], 1_000).unwrap()[0];
        let b = g.send_timed(0, 1, 0, &[2], 1_000).unwrap()[0];
        assert_eq!(g.cn_nics[0].rpc_messages(), 2);
        assert!(b.max(a) >= times[1], "separate sends cannot beat the merge");
        // The IOPS saving (paper 4.1): one message's send overhead
        // instead of two on the source NIC.
        assert!(
            g.cn_nics[0].busy_ns() > f.cn_nics[0].busy_ns(),
            "merging must save send-side NIC time: {} vs {}",
            g.cn_nics[0].busy_ns(),
            f.cn_nics[0].busy_ns()
        );
    }

    #[test]
    fn send_timed_to_failed_cn_charges_nothing() {
        let f = fabric(2, 1);
        f.set_failed(1, true);
        assert!(f.send_timed(0, 1, 0, &[1], 0).is_err());
        assert_eq!(f.cn_nics[0].rpc_messages(), 0);
        assert_eq!(f.cn_nics[0].op_count(), 0, "no queue charge on timeout");
    }

    #[test]
    fn send_async_at_charges_queues_and_returns_send_time() {
        let f = fabric(2, 1);
        let t_sent = f.send_async_at(0, 1, 0, 4, 500).unwrap();
        assert_eq!(t_sent, 500 + f.net.rpc_send_ns + f.net.cn_issue_ns);
        assert!(f.handler_busy_ns(1) >= f.net.rpc_handle_ns * 4);
        assert_eq!(f.cn_nics[0].rpc_messages(), 1);
    }

    #[test]
    fn handler_wait_is_queueing_delay_at_the_destination() {
        let f = fabric(3, 1);
        // First message to an idle handler: chunks arrive back-to-back, so
        // the first chunk waits 0 and each later chunk starts the instant
        // the previous finishes — still 0 queueing delay.
        f.send_timed(0, 1, 0, &[2, 3], 0).unwrap();
        assert_eq!(f.handler_wait_ns(1), 0, "idle queue: no wait");
        assert_eq!(f.handler_chunks(1), 2);
        // A second message sent at the same instant queues behind the
        // first's 5 requests: its chunk waits the full residual service.
        f.send_timed(2, 1, 0, &[1], 0).unwrap();
        assert_eq!(f.handler_chunks(1), 3);
        let wait = f.handler_wait_ns(1);
        assert!(wait > 0, "second message must queue: wait={wait}");
        assert!(
            wait <= f.net.rpc_handle_ns * 5,
            "wait bounded by the first message's service: {wait}"
        );
        // Attribution: the wait lands on the DESTINATION CN's NIC, and the
        // senders' NICs record none.
        assert_eq!(f.cn_nics[1].handler_wait_ns(), wait);
        assert_eq!(f.cn_nics[1].handler_chunks(), 3);
        assert_eq!(f.cn_nics[0].handler_wait_ns(), 0);
        assert_eq!(f.cn_nics[2].handler_wait_ns(), 0);
        // Mean + p99 surface through the fabric.
        assert!(f.mean_handler_wait_ns(1) > 0.0);
        assert!(f.handler_wait_p99_ns() > 0);
        assert_eq!(f.mean_handler_wait_ns(0), 0.0);
    }

    #[test]
    fn handler_backlog_probe_sees_pre_send_congestion() {
        let f = fabric(2, 1);
        // Idle destination: no backlog at any send time.
        assert_eq!(f.handler_backlog_ns(1, 0, 0), 0);
        // Load the handler with 40 requests' worth of service.
        f.send_async_at(0, 1, 0, 40, 0).unwrap();
        let backlog = f.handler_backlog_ns(1, 0, 0);
        assert!(
            backlog > f.net.rpc_handle_ns * 30,
            "probe must see the booked queue: {backlog}"
        );
        // Far enough in the future the queue has drained.
        assert_eq!(f.handler_backlog_ns(1, 0, 1_000_000), 0);
    }

    #[test]
    fn reset_queues_clears_all_per_destination_state() {
        let f = fabric(2, 2);
        // Dirty every piece of per-destination queue state: busy time on
        // both slots, wait sums, chunk counts, and the wait histogram.
        f.send_async_at(0, 1, 0, 20, 0).unwrap();
        f.send_async_at(0, 1, 0, 1, 0).unwrap(); // queues -> nonzero wait
        f.send_async_at(0, 1, 1, 5, 0).unwrap();
        assert!(f.handler_busy_ns(1) > 0);
        assert!(f.handler_wait_ns(1) > 0);
        assert!(f.handler_chunks(1) > 0);
        assert!(f.handler_wait_p99_ns() > 0 || f.handler_chunks(1) > 0);
        f.reset_queues();
        assert_eq!(f.handler_busy_ns(1), 0, "handler busy time survives reset");
        assert_eq!(f.handler_wait_ns(1), 0, "wait sum survives reset");
        assert_eq!(f.handler_chunks(1), 0, "chunk count survives reset");
        assert_eq!(f.handler_wait_p99_ns(), 0, "wait histogram survives reset");
        assert_eq!(f.handler_backlog_ns(1, 0, 0), 0, "backlog survives reset");
        assert_eq!(f.handler_backlog_ns(1, 1, 0), 0);
        // The queues are genuinely idle again: a fresh send sees no wait.
        f.send_async_at(0, 1, 0, 1, 0).unwrap();
        assert_eq!(f.handler_wait_ns(1), 0);
        assert_eq!(f.handler_chunks(1), 1);
    }

    #[test]
    fn handler_queues_are_per_slot() {
        let f = fabric(2, 2);
        let mut c0 = VClock::zero();
        let mut c1 = VClock::zero();
        // Two slots handled in parallel: same arrival, no cross-queueing.
        f.call(0, 1, 0, 10, &mut c0).unwrap();
        f.call(0, 1, 1, 10, &mut c1).unwrap();
        // c1 may still pay NIC serialization, but not slot-0's handler time.
        let serial = f.net.rpc_handle_ns * 10;
        assert!(c1.now() < c0.now() + serial, "slots share a queue?");
    }

    #[test]
    fn dropped_message_surfaces_as_a_timeout_at_the_caller() {
        use crate::dm::faults::{FaultInjector, FaultRule};
        let f = fabric(2, 1);
        f.set_faults(Some(Arc::new(
            FaultInjector::new(1).rule(FaultRule::drop(1000)),
        )));
        let mut clk = VClock::zero();
        let err = f.call(0, 1, 0, 1, &mut clk).unwrap_err();
        assert!(matches!(err, Error::NodeUnavailable(_)));
        assert_eq!(clk.now(), f.timeout_ns(), "caller burns one timeout");
        assert_eq!(f.cn_nics[0].rpc_dropped(), 1);
        assert_eq!(f.cn_nics[0].rpc_messages(), 0, "a lost SEND is not a message");
        assert_eq!(f.handler_busy_ns(1), 0, "nothing reaches the handler");
        // Clearing the injector restores delivery.
        f.set_faults(None);
        f.call(0, 1, 0, 1, &mut clk).unwrap();
    }

    #[test]
    fn async_drop_pays_the_send_and_loses_the_message() {
        use crate::dm::faults::{FaultInjector, FaultRule};
        let f = fabric(2, 1);
        f.set_faults(Some(Arc::new(
            FaultInjector::new(2).rule(FaultRule::drop(1000)),
        )));
        let t_sent = f.send_async_at(0, 1, 0, 4, 500).unwrap();
        assert_eq!(t_sent, 500 + f.net.rpc_send_ns + f.net.cn_issue_ns);
        assert_eq!(f.cn_nics[0].rpc_dropped(), 1);
        assert_eq!(f.handler_busy_ns(1), 0, "the payload never arrives");
    }

    #[test]
    fn gray_slow_multiplies_handler_service_and_feeds_the_wait_signal() {
        use crate::dm::faults::{FaultInjector, FaultRule};
        let plain = fabric(2, 1);
        let done_plain = plain.send_timed(0, 1, 0, &[2], 1_000).unwrap()[0];
        let gray = fabric(2, 1);
        gray.set_faults(Some(Arc::new(
            FaultInjector::new(3).rule(FaultRule::gray_slow(4, 1000)),
        )));
        let done_gray = gray.send_timed(0, 1, 0, &[2], 1_000).unwrap()[0];
        assert_eq!(
            done_gray - done_plain,
            plain.net.rpc_handle_ns * 2 * 3,
            "4x service on a 2-request chunk costs 3 extra service units"
        );
        // A second message behind the gray chunk sees the inflated
        // backlog through the normal queueing-delay signal.
        gray.set_faults(None);
        gray.send_timed(0, 1, 0, &[1], 1_000).unwrap();
        plain.send_timed(0, 1, 0, &[1], 1_000).unwrap();
        assert!(
            gray.handler_wait_ns(1) > plain.handler_wait_ns(1),
            "gray service must surface as handler_wait_ns at the destination"
        );
    }

    #[test]
    fn delayed_message_arrives_exactly_that_much_later() {
        use crate::dm::faults::{FaultInjector, FaultRule};
        let plain = fabric(2, 1);
        let done_plain = plain.send_timed(0, 1, 0, &[1], 0).unwrap()[0];
        let slow = fabric(2, 1);
        slow.set_faults(Some(Arc::new(
            FaultInjector::new(4).rule(FaultRule::delay(9_000, 1000)),
        )));
        let done_slow = slow.send_timed(0, 1, 0, &[1], 0).unwrap()[0];
        assert_eq!(done_slow - done_plain, 9_000);
    }

    #[test]
    fn empty_injector_is_byte_inert() {
        use crate::dm::faults::FaultInjector;
        let plain = fabric(3, 2);
        let inert = fabric(3, 2);
        inert.set_faults(Some(Arc::new(FaultInjector::new(42))));
        for (src, dst, slot, owners, t) in [
            (0usize, 1usize, 0usize, vec![3usize, 2], 1_000u64),
            (2, 1, 1, vec![1], 2_500),
            (0, 2, 0, vec![4, 4, 1], 4_000),
        ] {
            let a = plain.send_timed(src, dst, slot, &owners, t).unwrap();
            let b = inert.send_timed(src, dst, slot, &owners, t).unwrap();
            assert_eq!(a, b, "an empty injector must not perturb timing");
        }
        let a = plain.send_async_at(1, 0, 0, 5, 9_000).unwrap();
        let b = inert.send_async_at(1, 0, 0, 5, 9_000).unwrap();
        assert_eq!(a, b);
        for cn in 0..3 {
            assert_eq!(
                plain.cn_nics[cn].rpc_messages(),
                inert.cn_nics[cn].rpc_messages()
            );
            assert_eq!(plain.handler_wait_ns(cn), inert.handler_wait_ns(cn));
            assert_eq!(inert.cn_nics[cn].rpc_dropped(), 0);
        }
    }
}
