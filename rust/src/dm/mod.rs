//! Disaggregated-memory fabric substrate.
//!
//! The paper's testbed is 12 physical machines (3 MNs + 9 CNs) on 56 Gbps
//! ConnectX-3 InfiniBand. We do not have that hardware, so this module
//! implements the closest synthetic equivalent that exercises the same
//! code paths (DESIGN.md substitution table):
//!
//! - **Real shared memory**: MN memory is a word array of atomics; every
//!   READ/WRITE/CAS/FAA actually executes, so concurrency-control
//!   correctness is real, not modelled.
//! - **Calibrated network costs in virtual time**: every verb is *also*
//!   charged against a queueing model — per-RNIC FIFO queues
//!   (`busy_until` atomics) with per-verb service times taken from the
//!   paper's measurements (35 Mops WRITE vs **2.5 Mops CAS** on the MN
//!   RNIC) plus an RTT and a bandwidth term. Coordinators carry virtual
//!   clocks; a [`clock::TimeGate`] keeps concurrent clocks within a small
//!   window so virtual-time contention stays faithful.
//!
//! This reproduces the paper's causal bottleneck: CAS-heavy lock traffic
//! saturates MN RNICs first (fig. 2), and moving locks into CN CPUs
//! removes that queue (fig. 3 and LOTUS proper).

pub mod clock;
pub mod faults;
pub mod memnode;
pub mod netconfig;
pub mod opbatch;
pub mod rnic;
pub mod rpc;
pub mod verbs;

pub use clock::{TimeGate, VClock};
pub use faults::{DoorbellFault, FaultAction, FaultInjector, FaultMode, FaultRule, FaultsCell};
pub use memnode::{MemNode, MemRegion};
pub use netconfig::NetConfig;
pub use opbatch::{BatchResult, BufPool, MergedBatch, MergedResult, OpBatch, OpTag};
pub use rnic::Rnic;
pub use rpc::RpcFabric;
pub use verbs::{Endpoint, RingOutcome, VerbOp};
