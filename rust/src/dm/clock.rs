//! Virtual time: per-coordinator clocks and the cross-coordinator gate.
//!
//! Every coordinator thread owns a [`VClock`] (u64 virtual ns) advanced by
//! the cost model. Real threads execute at wall speed, so without
//! coupling, one coordinator's virtual clock could race far ahead of
//! another's and contention would be computed between events that are not
//! actually concurrent. [`TimeGate`] bounds that skew: each coordinator
//! publishes its clock and may only proceed while it is within `window_ns`
//! of the slowest live coordinator (a conservative discrete-event
//! synchronization, cf. conservative PDES null-message windows).

use std::sync::atomic::{AtomicU64, Ordering};

/// A coordinator's private virtual clock (ns).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct VClock(pub u64);

impl VClock {
    /// Time zero.
    pub fn zero() -> Self {
        VClock(0)
    }

    /// Advance by `ns` and return the new time.
    #[inline]
    pub fn advance(&mut self, ns: u64) -> u64 {
        self.0 += ns;
        self.0
    }

    /// Jump to `t` if `t` is later.
    #[inline]
    pub fn catch_up(&mut self, t: u64) {
        if t > self.0 {
            self.0 = t;
        }
    }

    /// Current time (ns).
    #[inline]
    pub fn now(&self) -> u64 {
        self.0
    }
}

/// Bounded-skew synchronizer across coordinator threads.
pub struct TimeGate {
    clocks: Vec<AtomicU64>,
    cached_min: AtomicU64,
    window_ns: u64,
}

impl TimeGate {
    /// Gate for `n` coordinators with the given skew window.
    pub fn new(n: usize, window_ns: u64) -> Self {
        Self {
            clocks: (0..n).map(|_| AtomicU64::new(0)).collect(),
            cached_min: AtomicU64::new(0),
            window_ns,
        }
    }

    /// Number of registered coordinators.
    pub fn len(&self) -> usize {
        self.clocks.len()
    }

    /// True if the gate tracks no coordinators.
    pub fn is_empty(&self) -> bool {
        self.clocks.is_empty()
    }

    fn scan_min(&self) -> u64 {
        let mut min = u64::MAX;
        for c in &self.clocks {
            let v = c.load(Ordering::Acquire);
            if v < min {
                min = v;
            }
        }
        // Publish so other coordinators can skip their own scans. A plain
        // store (not fetch_max): the pipelined scheduler publishes its
        // currently pumped lane's clock, which *regresses* when it
        // switches to a slower lane — a sticky max would let the fast
        // path run unboundedly far ahead of the true slowest clock.
        // Racing stores are fine: every stored value is a genuinely
        // scanned min from some recent instant, and the slow path
        // rescans.
        self.cached_min.store(min, Ordering::Release);
        min
    }

    /// Publish `now` for coordinator `id` and block (spin-yield) until the
    /// slowest live coordinator is within the window.
    pub fn sync(&self, id: usize, now: u64) {
        self.clocks[id].store(now, Ordering::Release);
        if now <= self.cached_min.load(Ordering::Acquire).saturating_add(self.window_ns) {
            return;
        }
        loop {
            let min = self.scan_min();
            if now <= min.saturating_add(self.window_ns) {
                return;
            }
            std::thread::yield_now();
        }
    }

    /// Mark coordinator `id` finished so it never blocks others.
    pub fn finish(&self, id: usize) {
        self.clocks[id].store(u64::MAX, Ordering::Release);
    }

    /// Lowest live clock (u64::MAX when all are finished).
    pub fn min_clock(&self) -> u64 {
        self.scan_min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn vclock_advances() {
        let mut c = VClock::zero();
        assert_eq!(c.advance(10), 10);
        assert_eq!(c.advance(5), 15);
        c.catch_up(12); // older — no-op
        assert_eq!(c.now(), 15);
        c.catch_up(40);
        assert_eq!(c.now(), 40);
    }

    #[test]
    fn gate_allows_within_window() {
        let g = TimeGate::new(2, 1000);
        g.sync(0, 100); // other clock is 0, skew 100 <= 1000 — no block
        g.sync(1, 900);
        assert!(g.min_clock() <= 900);
    }

    #[test]
    fn gate_blocks_until_peer_advances() {
        let g = Arc::new(TimeGate::new(2, 100));
        let g2 = g.clone();
        let t = std::thread::spawn(move || {
            // Coordinator 0 wants to reach t=10_000; it must wait for 1.
            g2.sync(0, 10_000);
            10_000u64
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(!t.is_finished(), "should be gated on coordinator 1");
        g.sync(1, 9_950);
        let v = t.join().unwrap();
        assert_eq!(v, 10_000);
    }

    #[test]
    fn finished_coordinator_never_blocks() {
        let g = TimeGate::new(2, 10);
        g.finish(1);
        g.sync(0, 1_000_000); // must not block
    }

    #[test]
    fn min_clock_tracks_slowest() {
        let g = TimeGate::new(3, u64::MAX);
        g.sync(0, 500);
        g.sync(1, 100);
        g.sync(2, 900);
        assert_eq!(g.min_clock(), 100);
    }
}
