//! Virtual time: per-coordinator clocks and the cross-coordinator gate.
//!
//! Every coordinator thread owns a [`VClock`] (u64 virtual ns) advanced by
//! the cost model. Real threads execute at wall speed, so without
//! coupling, one coordinator's virtual clock could race far ahead of
//! another's and contention would be computed between events that are not
//! actually concurrent. [`TimeGate`] bounds that skew: each coordinator
//! publishes its clock and may only proceed while it is within `window_ns`
//! of the slowest live coordinator (a conservative discrete-event
//! synchronization, cf. conservative PDES null-message windows).
//!
//! # Epoch-batched publication (ISSUE 9)
//!
//! At paper scale the gate itself becomes the wall-clock bottleneck:
//! every lane clock bump is a cross-core `AtomicU64` store that every
//! peer's scan reads back. [`TimeGate::publish`] batches publication
//! into epochs of `publish_ns` virtual progress: a store is paid only
//! when the coordinator advanced at least `publish_ns` past its last
//! *published* value, or when it may have left the skew window (then it
//! must publish its true clock and block — [`TimeGate::sync`]). The
//! published clock is thus a conservative bound on the true clock, stale
//! by less than `publish_ns`, and the realized skew bound widens from
//! `window_ns` to `window_ns + publish_ns`. With `publish_ns == 0` (the
//! default) every call publishes — byte-identical to the legacy per-bump
//! behavior.

use std::sync::atomic::{AtomicU64, Ordering};

/// A coordinator's private virtual clock (ns).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct VClock(pub u64);

impl VClock {
    /// Time zero.
    pub fn zero() -> Self {
        VClock(0)
    }

    /// Advance by `ns` and return the new time.
    #[inline]
    pub fn advance(&mut self, ns: u64) -> u64 {
        self.0 += ns;
        self.0
    }

    /// Jump to `t` if `t` is later.
    #[inline]
    pub fn catch_up(&mut self, t: u64) {
        if t > self.0 {
            self.0 = t;
        }
    }

    /// Current time (ns).
    #[inline]
    pub fn now(&self) -> u64 {
        self.0
    }
}

/// One coordinator's published clock, padded to its own cache line: the
/// owner stores it, every blocked peer scans it, and without the padding
/// neighbouring coordinators' stores false-share one line and the gate
/// serializes on cache-coherence traffic instead of virtual time.
#[repr(align(64))]
struct ClockSlot(AtomicU64);

/// Spin-then-park backoff for the gate's blocking slow path. A bare
/// `yield_now` loop burns a core per blocked coordinator, which at paper
/// scale (dozens of coordinator threads on a few cores) starves the very
/// peers the waiter is gated on. Escalates: busy spins, then scheduler
/// yields, then short parks.
struct Backoff(u32);

impl Backoff {
    const SPIN_LIMIT: u32 = 64;
    const YIELD_LIMIT: u32 = 96;
    const PARK_NS: u64 = 20_000;

    fn new() -> Self {
        Backoff(0)
    }

    fn wait(&mut self) {
        let round = self.0;
        self.0 = self.0.saturating_add(1);
        if round < Self::SPIN_LIMIT {
            std::hint::spin_loop();
        } else if round < Self::YIELD_LIMIT {
            std::thread::yield_now();
        } else {
            std::thread::sleep(std::time::Duration::from_nanos(Self::PARK_NS));
        }
    }
}

/// Bounded-skew synchronizer across coordinator threads.
pub struct TimeGate {
    clocks: Vec<ClockSlot>,
    cached_min: AtomicU64,
    window_ns: u64,
    /// Publication epoch (virtual ns); 0 == publish on every call.
    publish_ns: u64,
}

impl TimeGate {
    /// Gate for `n` coordinators with the given skew window (per-bump
    /// publication; see [`TimeGate::with_publish`]).
    pub fn new(n: usize, window_ns: u64) -> Self {
        Self {
            clocks: (0..n).map(|_| ClockSlot(AtomicU64::new(0))).collect(),
            cached_min: AtomicU64::new(0),
            window_ns,
            publish_ns: 0,
        }
    }

    /// Set the publication epoch: [`TimeGate::publish`] skips the
    /// cross-core store while the caller is within `publish_ns` of its
    /// last published clock *and* safely inside the skew window.
    pub fn with_publish(mut self, publish_ns: u64) -> Self {
        self.publish_ns = publish_ns;
        self
    }

    /// Number of registered coordinators.
    pub fn len(&self) -> usize {
        self.clocks.len()
    }

    /// True if the gate tracks no coordinators.
    pub fn is_empty(&self) -> bool {
        self.clocks.is_empty()
    }

    fn scan_min(&self) -> u64 {
        let mut min = u64::MAX;
        for c in &self.clocks {
            let v = c.0.load(Ordering::Acquire);
            if v < min {
                min = v;
            }
        }
        // Publish so other coordinators can skip their own scans. A plain
        // store (not fetch_max): the pipelined scheduler publishes its
        // currently pumped lane's clock, which *regresses* when it
        // switches to a slower lane — a sticky max would let the fast
        // path run unboundedly far ahead of the true slowest clock.
        // Racing stores are fine: every stored value is a genuinely
        // scanned min from some recent instant, and the slow path
        // rescans. A fully drained gate (every coordinator finished,
        // `min == u64::MAX`) keeps the last *live* min instead: the
        // report path calls this after `finish()`, and caching the
        // sentinel would hand late readers of the fast path a bogus
        // "everyone is at the end of time" floor.
        if min != u64::MAX {
            self.cached_min.store(min, Ordering::Release);
        }
        min
    }

    /// Publish `now` for coordinator `id`, epoch-batched: skip the store
    /// while within `publish_ns` of the last published clock and safely
    /// inside the skew window (see the module docs). Falls through to
    /// [`TimeGate::sync`] — publishing the true clock first, so two
    /// mutually stale coordinators can never deadlock on each other's
    /// old values — whenever the epoch is exhausted or blocking may be
    /// required. With `publish_ns == 0` this *is* `sync`.
    #[inline]
    pub fn publish(&self, id: usize, now: u64) {
        if self.publish_ns > 0 {
            // `abs_diff`, not a subtraction: a regressed clock (lane
            // switch) farther than the epoch below the published value
            // must re-publish, restoring the conservative bound.
            let last = self.clocks[id].0.load(Ordering::Relaxed);
            if now.abs_diff(last) < self.publish_ns
                && now
                    <= self
                        .cached_min
                        .load(Ordering::Acquire)
                        .saturating_add(self.window_ns)
            {
                return;
            }
        }
        self.sync(id, now);
    }

    /// Publish `now` for coordinator `id` and block (spin, then yield,
    /// then park) until the slowest live coordinator is within the
    /// window.
    pub fn sync(&self, id: usize, now: u64) {
        self.clocks[id].0.store(now, Ordering::Release);
        if now <= self.cached_min.load(Ordering::Acquire).saturating_add(self.window_ns) {
            return;
        }
        let mut backoff = Backoff::new();
        loop {
            let min = self.scan_min();
            if now <= min.saturating_add(self.window_ns) {
                return;
            }
            backoff.wait();
        }
    }

    /// Mark coordinator `id` finished so it never blocks others.
    pub fn finish(&self, id: usize) {
        self.clocks[id].0.store(u64::MAX, Ordering::Release);
    }

    /// Lowest live clock (u64::MAX when all are finished).
    pub fn min_clock(&self) -> u64 {
        self.scan_min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn vclock_advances() {
        let mut c = VClock::zero();
        assert_eq!(c.advance(10), 10);
        assert_eq!(c.advance(5), 15);
        c.catch_up(12); // older — no-op
        assert_eq!(c.now(), 15);
        c.catch_up(40);
        assert_eq!(c.now(), 40);
    }

    #[test]
    fn gate_allows_within_window() {
        let g = TimeGate::new(2, 1000);
        g.sync(0, 100); // other clock is 0, skew 100 <= 1000 — no block
        g.sync(1, 900);
        assert!(g.min_clock() <= 900);
    }

    #[test]
    fn gate_blocks_until_peer_advances() {
        let g = Arc::new(TimeGate::new(2, 100));
        let g2 = g.clone();
        let t = std::thread::spawn(move || {
            // Coordinator 0 wants to reach t=10_000; it must wait for 1.
            g2.sync(0, 10_000);
            10_000u64
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(!t.is_finished(), "should be gated on coordinator 1");
        g.sync(1, 9_950);
        let v = t.join().unwrap();
        assert_eq!(v, 10_000);
    }

    #[test]
    fn finished_coordinator_never_blocks() {
        let g = TimeGate::new(2, 10);
        g.finish(1);
        g.sync(0, 1_000_000); // must not block
    }

    #[test]
    fn min_clock_tracks_slowest() {
        let g = TimeGate::new(3, u64::MAX);
        g.sync(0, 500);
        g.sync(1, 100);
        g.sync(2, 900);
        assert_eq!(g.min_clock(), 100);
    }

    #[test]
    fn drained_gate_keeps_last_live_cached_min() {
        // Satellite fix: after every coordinator finished, the report
        // path's scans must not cache the u64::MAX sentinel — a late
        // fast-path reader would inherit an "infinite" floor.
        let g = TimeGate::new(2, 100);
        g.sync(0, 50);
        g.sync(1, 80);
        assert_eq!(g.min_clock(), 50);
        g.finish(0);
        assert_eq!(g.min_clock(), 80);
        assert_eq!(g.cached_min.load(Ordering::Acquire), 80);
        g.finish(1);
        assert_eq!(g.min_clock(), u64::MAX, "drained gate reports MAX");
        assert_eq!(
            g.cached_min.load(Ordering::Acquire),
            80,
            "cached min keeps the last live value, not the sentinel"
        );
    }

    #[test]
    fn publish_zero_epoch_matches_per_bump_publication() {
        // publish_ns == 0 is the legacy behavior: every publish stores.
        let g = TimeGate::new(2, 1000);
        g.publish(0, 40);
        g.publish(1, 60);
        assert_eq!(g.min_clock(), 40);
        g.publish(0, 70);
        assert_eq!(g.min_clock(), 60);
    }

    #[test]
    fn publish_batches_stores_into_epochs() {
        let g = TimeGate::new(1, 1_000).with_publish(500);
        g.publish(0, 100); // within epoch AND window: store skipped
        assert_eq!(g.min_clock(), 0, "stale published clock kept");
        g.publish(0, 600); // epoch exhausted: must publish
        assert_eq!(g.min_clock(), 600);
        g.publish(0, 700); // new epoch, within window: skipped again
        assert_eq!(g.min_clock(), 600);
    }

    #[test]
    fn throttled_publisher_still_blocks_beyond_window() {
        // The epoch only batches *stores*; the bounded-skew invariant is
        // untouched. A publisher leaving the window publishes its true
        // clock and blocks exactly like sync.
        let g = Arc::new(TimeGate::new(2, 100).with_publish(1_000_000));
        let g2 = g.clone();
        let t = std::thread::spawn(move || {
            g2.publish(0, 500);
            true
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(!t.is_finished(), "should be gated on coordinator 1");
        g.publish(1, 450); // beyond the cached window: publishes too
        assert!(t.join().unwrap());
        assert_eq!(g.min_clock(), 450);
    }
}
