//! Lock-rebuild-free recovery of CN failures (paper section 6).
//!
//! Runs on a *surviving* coordinator (recovery "proceeds independently of
//! CN recovery" and "does not depend on the CN's restart"); every memory
//! access is charged to that coordinator's virtual clock so the fig. 15
//! timeline reflects real recovery cost.

use crate::dm::clock::VClock;
use crate::dm::verbs::{Endpoint, VerbOp};
use crate::store::cvt::INVISIBLE;
use crate::txn::coordinator::SharedCluster;
use crate::txn::log::{slot_size, LogRecord, STATE_EMPTY};
use crate::Result;

/// Outcome of one recovery pass.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RecoveryReport {
    /// Log slots scanned.
    pub scanned_logs: usize,
    /// In-flight commits completed (all versions already visible).
    pub completed: usize,
    /// In-flight commits rolled back (INVISIBLE versions invalidated).
    pub rolled_back: usize,
    /// Locks released on surviving CNs on behalf of the failed CNs.
    pub released_locks: usize,
    /// Surviving transactions doomed (their locks lived on a failed CN).
    pub doomed_txns: usize,
    /// PREPARED slots whose seal did not verify (torn log writes,
    /// PR 8): discarded — the transaction never reached its commit
    /// point intact, so the old versions stand untouched.
    pub torn_slots_discarded: usize,
    /// Virtual time the pass took (ns).
    pub duration_ns: u64,
}

/// Recover from the fail-stop failure of `failed` CNs.
///
/// `ep` / `clk` belong to the surviving coordinator executing the
/// procedure. Concurrent failures are handled in one pass (the paper:
/// recovery "decomposed into independent tasks ... handled in parallel").
pub fn recover_cn_failure(
    cluster: &SharedCluster,
    failed: &[usize],
    ep: &Endpoint,
    clk: &mut VClock,
) -> Result<RecoveryReport> {
    let t0 = clk.now();
    let mut report = RecoveryReport::default();

    // --- 1. Transaction recovery: scan the failed CNs' commit logs. ---
    let per_cn = cluster.cfg.coordinators_per_cn;
    for &cn in failed {
        for slot in 0..per_cn {
            let gid = cn * per_cn + slot;
            let (log_mn, log_addr) = cluster.log_slots[gid];
            let mn = &cluster.mns[log_mn];
            let buf = ep.read(mn, log_addr, slot_size() as usize, clk)?;
            report.scanned_logs += 1;
            let rec = LogRecord::parse(&buf);
            if rec.is_torn() {
                // A PREPARED state word over a broken seal: the log
                // write tore (crash or torn doorbell mid-slot). The
                // transaction never reached its commit point intact —
                // discard the slot; the old versions stand as the undo
                // log and the lock cleanup below frees its locks.
                report.torn_slots_discarded += 1;
                let mut ops = [VerbOp::Write {
                    addr: log_addr,
                    data: STATE_EMPTY.to_le_bytes().to_vec(),
                }];
                ep.doorbell(mn, &mut ops, clk)?;
                continue;
            }
            if !rec.is_prepared() {
                continue;
            }
            // Classify the listed CVT cells: one 16-byte read covers the
            // cell's head word (cv | valid) and its version word. An
            // entry whose live cv differs from the logged one has been
            // *recycled* by a later transaction — it is not ours to roll
            // back (doing so would destroy that transaction's committed
            // data); it only means our slot clear raced the crash.
            let mut ours: Vec<(usize, u64)> = Vec::new();
            let mut any_invisible = false;
            for (i, e) in rec.entries.iter().enumerate() {
                let img = ep.read(&cluster.mns[e.mn as usize], e.cell_addr, 16, clk)?;
                let live_cv = img[0];
                let version = u64::from_le_bytes(img[8..16].try_into().unwrap());
                if live_cv != e.cv {
                    continue; // recycled: a later committed txn owns it now
                }
                ours.push((i, version));
                if version == INVISIBLE {
                    any_invisible = true;
                }
            }
            if !any_invisible {
                // Commit already took effect on every primary (past
                // Write Visible there): the transaction "continues its
                // commit phase" — roll the visibility sweep FORWARD
                // onto the backups. A torn sweep may have flipped the
                // primaries while a backup's ring was cut; a backup
                // left INVISIBLE would serve the old version after an
                // MN failover. The write is idempotent for backups the
                // sweep already reached.
                for &(i, version) in &ours {
                    let e = &rec.entries[i];
                    let table = cluster.table(e.table);
                    for r in 1..table.replicas.len() {
                        let cell_addr = table.to_replica_addr(e.cell_addr, r);
                        let mut ops = [VerbOp::Write {
                            addr: cell_addr + 8,
                            data: version.to_le_bytes().to_vec(),
                        }];
                        ep.doorbell(&cluster.mns[table.replicas[r].mn], &mut ops, clk)?;
                    }
                }
                report.completed += 1;
            } else {
                // Some versions still INVISIBLE: abort. Invalidate every
                // cell the transaction still owns — including ones a
                // torn visibility sweep already flipped, so the undo is
                // atomic (old versions are the undo log) — on every
                // replica.
                for &(i, _) in &ours {
                    let e = &rec.entries[i];
                    let table = cluster.table(e.table);
                    for r in 0..table.replicas.len() {
                        let cell_addr = table.to_replica_addr(e.cell_addr, r);
                        // Clear the `valid` byte (word 0 of the cell holds
                        // head_cv|valid; writing 0 also resets the CV,
                        // which is safe: the cell is invalid).
                        let mut ops = [VerbOp::Write {
                            addr: cell_addr,
                            data: 0u64.to_le_bytes().to_vec(),
                        }];
                        ep.doorbell(&cluster.mns[table.replicas[r].mn], &mut ops, clk)?;
                    }
                }
                report.rolled_back += 1;
            }
            // Clear the slot so a second recovery pass is a no-op.
            let mut ops = [VerbOp::Write {
                addr: log_addr,
                data: STATE_EMPTY.to_le_bytes().to_vec(),
            }];
            ep.doorbell(mn, &mut ops, clk)?;
        }
    }

    // --- 2. Lock cleanup on surviving CNs. ---
    for (cn, svc) in cluster.lock_services.iter().enumerate() {
        if failed.contains(&cn) {
            continue;
        }
        for &f in failed {
            let txns = svc.release_all_of_cn(f);
            report.released_locks += txns.len();
        }
    }

    // --- 3. Doom surviving transactions whose locks lived on failed CNs,
    //        then wipe the failed lock tables (rebuild-free). ---
    for &f in failed {
        let svc = &cluster.lock_services[f];
        let mut doomed = Vec::new();
        for survivor_cn in 0..cluster.cfg.n_cns {
            if failed.contains(&survivor_cn) {
                continue;
            }
            doomed.extend(
                svc.state()
                    .held_by_cn(survivor_cn)
                    .into_iter()
                    .map(|(_, _, h)| h.txn),
            );
        }
        doomed.sort_unstable();
        doomed.dedup();
        report.doomed_txns += doomed.len();
        cluster.doomed.doom_all(doomed);
        svc.clear();
        cluster.vt_caches[f].clear();
        cluster.addr_caches[f].clear();
    }

    report.duration_ns = clk.now() - t0;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::lock::table::LockMode;
    use crate::sharding::key::LotusKey;
    use crate::sim::Cluster;
    use crate::store::index::TableSpec;
    use crate::txn::api::{RecordRef, TxnApi};
    use crate::txn::coordinator::LotusCoordinator;
    use std::sync::Arc;

    fn mini() -> (Arc<SharedCluster>, Vec<LotusCoordinator>) {
        let mut cfg = Config::small();
        cfg.n_cns = 3;
        cfg.coordinators_per_cn = 2;
        let specs = vec![TableSpec {
            id: 0,
            name: "t".into(),
            record_len: 40,
            ncells: 2,
            assoc: 4,
            expected_records: 16384,
        }];
        let cluster = Cluster::build_shared(&cfg, specs).unwrap();
        for uid in 0..4096u64 {
            cluster.tables[0]
                .load_insert(
                    &cluster.mns,
                    LotusKey::compose(uid, uid),
                    format!("v-{uid}").as_bytes(),
                    1,
                )
                .unwrap();
        }
        let coords = (0..6)
            .map(|g| LotusCoordinator::new(cluster.clone(), g / 2, g % 2, g))
            .collect();
        (cluster, coords)
    }

    fn recovery_ep(c: &Arc<SharedCluster>, cn: usize) -> (Endpoint, VClock) {
        (
            Endpoint::new(cn, c.cn_nics[cn].clone(), c.net.clone()),
            VClock::zero(),
        )
    }

    #[test]
    fn clean_cluster_recovers_trivially() {
        let (c, _coords) = mini();
        let (ep, mut clk) = recovery_ep(&c, 1);
        let rep = recover_cn_failure(&c, &[0], &ep, &mut clk).unwrap();
        assert_eq!(rep.completed + rep.rolled_back, 0);
        assert_eq!(rep.released_locks, 0);
        assert!(rep.duration_ns > 0, "log scan must cost time");
        assert_eq!(rep.scanned_logs, 2);
    }

    #[test]
    fn failed_cn_locks_released_everywhere() {
        let (c, mut coords) = mini();
        // CN0's coordinator takes locks on keys spread over owners.
        let co = &mut coords[0];
        co.begin(false);
        for uid in [1u64, 5, 9, 13, 21] {
            co.txn().add_rw(RecordRef::new(0, LotusKey::compose(uid, uid)));
        }
        co.txn().execute().unwrap();
        let held_before: usize = c.lock_services.iter().map(|s| s.held_slots()).sum();
        assert!(held_before >= 5);
        // CN0 dies mid-transaction.
        let (ep, mut clk) = recovery_ep(&c, 1);
        recover_cn_failure(&c, &[0], &ep, &mut clk).unwrap();
        let held_after: usize = c.lock_services.iter().map(|s| s.held_slots()).sum();
        assert_eq!(held_after, 0, "all of the failed CN's locks must be freed");
    }

    #[test]
    fn survivor_with_locks_on_failed_cn_is_doomed() {
        let (c, mut coords) = mini();
        // A CN1 coordinator locks a key whose lock lives on CN2.
        let uid = (0..4096u64)
            .find(|&u| c.router.owner_of_key(LotusKey::compose(u, u)) == 2)
            .unwrap();
        let co = &mut coords[2]; // CN1, slot 0
        assert_eq!(co.cn, 1);
        co.begin(false);
        co.txn().add_rw(RecordRef::new(0, LotusKey::compose(uid, uid)));
        co.txn().execute().unwrap();
        co.txn()
            .stage_write(RecordRef::new(0, LotusKey::compose(uid, uid)), b"x".to_vec());
        // CN2 fails; recovery dooms the CN1 transaction.
        let (ep, mut clk) = recovery_ep(&c, 0);
        let rep = recover_cn_failure(&c, &[2], &ep, &mut clk).unwrap();
        assert_eq!(rep.doomed_txns, 1);
        // The commit must now abort.
        assert!(coords[2].txn().commit().is_err());
    }

    #[test]
    fn prepared_log_with_invisible_cells_rolls_back() {
        let (c, mut coords) = mini();
        let key = LotusKey::compose(7, 7);
        let r = RecordRef::new(0, key);
        // Manually simulate a CN0 coordinator crashing between
        // "Write Data & Log" and "Write Visible": run the writes by hand.
        let co = &mut coords[0];
        co.begin(false);
        co.txn().add_rw(r);
        co.txn().execute().unwrap();
        co.txn().stage_write(r, b"halfway".to_vec());
        // Cheat: write data + log exactly as commit would, then "crash".
        // We reuse commit() but doom the txn right after the data write is
        // impossible from outside, so instead craft the log directly:
        let table = c.table(0);
        let bucket = table.bucket_of(key);
        let mut bucket_buf = vec![0u8; table.layout.bucket_size() as usize];
        c.mns[table.primary().mn]
            .read_bytes(table.bucket_addr(0, bucket), &mut bucket_buf)
            .unwrap();
        let (slot, cvt) = table.find_in_bucket(&bucket_buf, key).unwrap();
        // Pick the free cell (ncells=2, only cell 0 used by the load).
        let cell_idx = 1u8;
        let cell_addr = table.cvt_addr(0, bucket, slot) + table.layout.cell_off(cell_idx);
        let rec_addr = table.record_addr(0, bucket, slot, cell_idx);
        for rr in 0..table.replicas.len() {
            let mn = &c.mns[table.replicas[rr].mn];
            let img = crate::store::record::encode(1, b"halfway", table.spec.record_len);
            mn.write_bytes(table.to_replica_addr(rec_addr, rr), &img).unwrap();
            let cell = crate::store::cvt::CellSnapshot {
                cv: 1,
                valid: true,
                len: 7,
                version: INVISIBLE,
                addr: rec_addr,
                consistent: true,
            };
            mn.write_bytes(
                table.to_replica_addr(cell_addr, rr),
                &crate::store::cvt::CvtSnapshot::serialize_cell(&cell),
            )
            .unwrap();
        }
        let gid = 0; // CN0 slot 0
        let (log_mn, log_addr) = c.log_slots[gid];
        let log = LogRecord::prepared(
            9999,
            vec![crate::txn::log::LogEntry {
                table: 0,
                mn: table.primary().mn as u16,
                cv: 1,
                cell_addr,
            }],
        )
        .unwrap();
        c.mns[log_mn].write_bytes(log_addr, &log.serialize()).unwrap();
        // Drop the in-flight txn state (the crash) and recover.
        coords[0].txn().rollback();
        let (ep, mut clk) = recovery_ep(&c, 1);
        let rep = recover_cn_failure(&c, &[0], &ep, &mut clk).unwrap();
        assert_eq!(rep.rolled_back, 1);
        assert_eq!(rep.completed, 0);
        // The INVISIBLE cell is invalidated; readers still see the old value.
        let got = table.load_get(&c.mns, 0, key).unwrap();
        assert_eq!(got, b"v-7");
        // Idempotent: a second pass scans an empty log.
        let rep2 = recover_cn_failure(&c, &[0], &ep, &mut clk).unwrap();
        assert_eq!(rep2.rolled_back, 0);
        let _ = cvt;
    }

    #[test]
    fn prepared_log_with_visible_cells_completes() {
        let (c, _coords) = mini();
        let table = c.table(0);
        let key = LotusKey::compose(9, 9);
        let bucket = table.bucket_of(key);
        let mut bucket_buf = vec![0u8; table.layout.bucket_size() as usize];
        c.mns[table.primary().mn]
            .read_bytes(table.bucket_addr(0, bucket), &mut bucket_buf)
            .unwrap();
        let (slot, _cvt) = table.find_in_bucket(&bucket_buf, key).unwrap();
        // Cell 0 is the loaded, *visible* version — log points at it.
        let cell_addr = table.cvt_addr(0, bucket, slot) + table.layout.cell_off(0);
        let mut cell_img = vec![0u8; 16];
        c.mns[table.primary().mn]
            .read_bytes(cell_addr, &mut cell_img)
            .unwrap();
        let (log_mn, log_addr) = c.log_slots[1];
        let log = LogRecord::prepared(
            8888,
            vec![crate::txn::log::LogEntry {
                table: 0,
                mn: table.primary().mn as u16,
                cv: cell_img[0],
                cell_addr,
            }],
        )
        .unwrap();
        c.mns[log_mn].write_bytes(log_addr, &log.serialize()).unwrap();
        let (ep, mut clk) = recovery_ep(&c, 1);
        let rep = recover_cn_failure(&c, &[0], &ep, &mut clk).unwrap();
        assert_eq!(rep.completed, 1);
        assert_eq!(rep.rolled_back, 0);
        // Data untouched.
        assert_eq!(table.load_get(&c.mns, 0, key).unwrap(), b"v-9");
    }

    #[test]
    fn torn_prepared_slot_is_discarded_never_replayed() {
        // PR 8: a torn commit-log write (strict prefix of the slot image
        // landed) reads as PREPARED over a broken seal. Recovery must
        // discard it — not roll anything back, not complete anything —
        // and the old versions must stand untouched.
        let (c, _coords) = mini();
        let table = c.table(0);
        let key = LotusKey::compose(11, 11);
        let bucket = table.bucket_of(key);
        let mut bucket_buf = vec![0u8; table.layout.bucket_size() as usize];
        c.mns[table.primary().mn]
            .read_bytes(table.bucket_addr(0, bucket), &mut bucket_buf)
            .unwrap();
        let (slot, _cvt) = table.find_in_bucket(&bucket_buf, key).unwrap();
        let cell_addr = table.cvt_addr(0, bucket, slot) + table.layout.cell_off(0);
        let full = LogRecord::prepared(
            4242,
            vec![crate::txn::log::LogEntry {
                table: 0,
                mn: table.primary().mn as u16,
                cv: 1,
                cell_addr,
            }],
        )
        .unwrap()
        .serialize();
        // Land only the first 24 bytes (state + txn + n) — the tear.
        let mut torn = vec![0u8; full.len()];
        torn[..24].copy_from_slice(&full[..24]);
        let (log_mn, log_addr) = c.log_slots[0];
        c.mns[log_mn].write_bytes(log_addr, &torn).unwrap();
        let (ep, mut clk) = recovery_ep(&c, 1);
        let rep = recover_cn_failure(&c, &[0], &ep, &mut clk).unwrap();
        assert_eq!(rep.torn_slots_discarded, 1);
        assert_eq!(rep.completed, 0);
        assert_eq!(rep.rolled_back, 0);
        assert_eq!(table.load_get(&c.mns, 0, key).unwrap(), b"v-11");
        // The discarded slot was cleared: a second pass is a no-op.
        let rep2 = recover_cn_failure(&c, &[0], &ep, &mut clk).unwrap();
        assert_eq!(rep2.torn_slots_discarded, 0);
    }

    #[test]
    fn recycled_cell_is_not_rolled_back() {
        // PR 8: a stale PREPARED slot (the clear raced the crash) whose
        // cell has since been recycled by a later committed transaction
        // (cv bumped) must NOT be invalidated — rolling it back would
        // destroy the later transaction's committed data.
        let (c, _coords) = mini();
        let table = c.table(0);
        let key = LotusKey::compose(13, 13);
        let bucket = table.bucket_of(key);
        let mut bucket_buf = vec![0u8; table.layout.bucket_size() as usize];
        c.mns[table.primary().mn]
            .read_bytes(table.bucket_addr(0, bucket), &mut bucket_buf)
            .unwrap();
        let (slot, _cvt) = table.find_in_bucket(&bucket_buf, key).unwrap();
        let cell_addr = table.cvt_addr(0, bucket, slot) + table.layout.cell_off(0);
        let mut cell_img = vec![0u8; 16];
        c.mns[table.primary().mn]
            .read_bytes(cell_addr, &mut cell_img)
            .unwrap();
        let live_cv = cell_img[0];
        // The stale slot logged the cell under an *older* cv.
        let log = LogRecord::prepared(
            5151,
            vec![crate::txn::log::LogEntry {
                table: 0,
                mn: table.primary().mn as u16,
                cv: live_cv.wrapping_sub(1),
                cell_addr,
            }],
        )
        .unwrap();
        let (log_mn, log_addr) = c.log_slots[0];
        c.mns[log_mn].write_bytes(log_addr, &log.serialize()).unwrap();
        let (ep, mut clk) = recovery_ep(&c, 1);
        let rep = recover_cn_failure(&c, &[0], &ep, &mut clk).unwrap();
        // Every entry was recycled: nothing pending, nothing destroyed.
        assert_eq!(rep.rolled_back, 0);
        assert_eq!(rep.completed, 1);
        assert_eq!(
            table.load_get(&c.mns, 0, key).unwrap(),
            b"v-13",
            "the recycled cell's committed data survived the stale slot"
        );
    }

    #[test]
    fn restarted_cn_starts_empty_and_serves() {
        let (c, mut coords) = mini();
        // Lock something on CN0, fail it, recover, restart.
        let uid = (0..4096u64)
            .find(|&u| c.router.owner_of_key(LotusKey::compose(u, u)) == 0)
            .unwrap();
        let key = LotusKey::compose(uid, uid);
        {
            let co = &mut coords[0];
            co.begin(false);
            co.txn().add_rw(RecordRef::new(0, key));
            co.txn().execute().unwrap();
        }
        c.membership.fail(0, 1000);
        c.rpc.set_failed(0, true);
        let (ep, mut clk) = recovery_ep(&c, 1);
        recover_cn_failure(&c, &[0], &ep, &mut clk).unwrap();
        assert_eq!(c.lock_services[0].held_slots(), 0);
        assert!(c.vt_caches[0].is_empty());
        // Restart: empty table serves new lock requests.
        c.rpc.set_failed(0, false);
        c.membership.complete_restart(0, 2000);
        let holder = crate::lock::state::HolderId { cn: 1, txn: 777 };
        assert!(c.lock_services[0]
            .try_acquire(&c.router, key, LockMode::Write, holder, true)
            .unwrap());
    }
}
