//! CN fault tolerance (paper section 6).
//!
//! LOTUS treats locks as **ephemeral**: a failed CN's lock table is never
//! reconstructed. Recovery decomposes into independent tasks running on
//! surviving CNs:
//!
//! 1. *Transaction recovery* — scan the failed CN's commit logs in the
//!    memory pool; transactions whose new versions are all visible
//!    complete, all others roll back (their INVISIBLE cells are
//!    invalidated, old versions serve as undo logs).
//! 2. *Lock cleanup* — surviving CNs release every lock held by the
//!    failed CN; transactions (from surviving CNs) whose locks lived *on*
//!    the failed CN are doomed unless already in their commit phase.
//! 3. *Restart* — the CN comes back with an **empty** lock table
//!    (lock-rebuild-free) and empty caches.
//!
//! [`membership`] provides the lease-based failure detector the paper
//! assumes; [`recovery`] implements the procedure.

pub mod membership;
pub mod recovery;

pub use membership::{Membership, NodeState};
pub use recovery::{recover_cn_failure, RecoveryReport};
