//! Lease-based membership service (paper section 6).
//!
//! The paper employs "a lease-based membership service [25, 31] to detect
//! node failures". In the simulator, failure *injection* flips a node to
//! `Failed` and failure *detection* is the lease expiry: queries made
//! within `lease_ns` of the failure still see the node as alive, modelling
//! the detection delay that shapes the fig. 15 recovery timeline.
//!
//! **Suspicion** is the false-positive side of lease churn: a node whose
//! lease renewal went missing is *suspected* over a virtual-time window
//! without being declared failed. Suspicion deliberately touches neither
//! the fail-stop state nor the epoch, and never triggers lock-table
//! clearing — a suspected-but-alive CN rejoins by simply outliving its
//! window (the ephemeral-locks invariant: no lock rebuild, no recovery
//! pass). Observers degrade gracefully instead: the lock phase
//! proactively aborts transactions that would wait on a suspected owner.

use std::sync::atomic::{AtomicU64, Ordering};

/// A CN's membership state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    /// Serving.
    Alive,
    /// Fail-stopped (lease may not have expired yet).
    Failed,
    /// Recovering: lock table cleared, not yet serving.
    Restarting,
}

const ST_ALIVE: u64 = 0;
const ST_FAILED: u64 = 1;
const ST_RESTARTING: u64 = 2;

struct Node {
    state: AtomicU64,
    /// Virtual time of the last state change.
    since: AtomicU64,
    /// Incarnation (bumps on every restart).
    epoch: AtomicU64,
    /// Suspicion window start (virtual ns; `u64::MAX` = not suspected).
    suspect_from: AtomicU64,
    /// Suspicion window end (virtual ns, exclusive).
    suspect_until: AtomicU64,
}

/// Cluster membership registry.
pub struct Membership {
    nodes: Vec<Node>,
    lease_ns: u64,
}

impl Membership {
    /// Registry for `n_cns` CNs with the given lease duration.
    pub fn new(n_cns: usize, lease_ns: u64) -> Self {
        Self {
            nodes: (0..n_cns)
                .map(|_| Node {
                    state: AtomicU64::new(ST_ALIVE),
                    since: AtomicU64::new(0),
                    epoch: AtomicU64::new(0),
                    suspect_from: AtomicU64::new(u64::MAX),
                    suspect_until: AtomicU64::new(u64::MAX),
                })
                .collect(),
            lease_ns,
        }
    }

    /// Number of registered CNs.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// Is the registry empty?
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Inject a fail-stop failure at virtual time `now`.
    pub fn fail(&self, cn: usize, now: u64) {
        self.nodes[cn].state.store(ST_FAILED, Ordering::Release);
        self.nodes[cn].since.store(now, Ordering::Release);
    }

    /// Begin restart (recovery cleared the node's state) at `now`.
    pub fn begin_restart(&self, cn: usize, now: u64) {
        self.nodes[cn].state.store(ST_RESTARTING, Ordering::Release);
        self.nodes[cn].since.store(now, Ordering::Release);
    }

    /// Complete restart: the node serves again with a new incarnation.
    pub fn complete_restart(&self, cn: usize, now: u64) {
        self.nodes[cn].epoch.fetch_add(1, Ordering::AcqRel);
        self.nodes[cn].state.store(ST_ALIVE, Ordering::Release);
        self.nodes[cn].since.store(now, Ordering::Release);
    }

    /// Raw state (no lease semantics).
    pub fn state(&self, cn: usize) -> NodeState {
        match self.nodes[cn].state.load(Ordering::Acquire) {
            ST_ALIVE => NodeState::Alive,
            ST_FAILED => NodeState::Failed,
            _ => NodeState::Restarting,
        }
    }

    /// Node incarnation.
    pub fn epoch(&self, cn: usize) -> u64 {
        self.nodes[cn].epoch.load(Ordering::Acquire)
    }

    /// Failure *detected* at `now`? True once the lease has expired.
    pub fn detected_failed(&self, cn: usize, now: u64) -> bool {
        self.state(cn) == NodeState::Failed
            && now >= self.nodes[cn].since.load(Ordering::Acquire) + self.lease_ns
    }

    /// Is the node serving (alive from the observer's perspective)?
    pub fn is_serving(&self, cn: usize) -> bool {
        self.state(cn) == NodeState::Alive
    }

    /// All CNs whose failure is detected at `now`.
    pub fn failed_at(&self, now: u64) -> Vec<usize> {
        (0..self.nodes.len())
            .filter(|&cn| self.detected_failed(cn, now))
            .collect()
    }

    /// Suspect `cn` over the virtual-time window `[from_ns, until_ns)`
    /// (a missed lease renewal, not a failure verdict). Does NOT touch
    /// the fail-stop state, the epoch, or any lock table — a false
    /// positive must be survivable without a recovery pass.
    pub fn suspect(&self, cn: usize, from_ns: u64, until_ns: u64) {
        self.nodes[cn].suspect_from.store(from_ns, Ordering::Release);
        self.nodes[cn].suspect_until.store(until_ns, Ordering::Release);
    }

    /// Clear any suspicion window on `cn` (e.g. between benchmark runs).
    pub fn clear_suspicion(&self, cn: usize) {
        self.nodes[cn].suspect_from.store(u64::MAX, Ordering::Release);
        self.nodes[cn].suspect_until.store(u64::MAX, Ordering::Release);
    }

    /// Is `cn` under suspicion at `now`? Purely window-based: a node can
    /// be suspected while genuinely alive (the false-positive case the
    /// lock phase degrades on) and outlives it with no state change.
    pub fn is_suspected(&self, cn: usize, now: u64) -> bool {
        let from = self.nodes[cn].suspect_from.load(Ordering::Acquire);
        from != u64::MAX
            && now >= from
            && now < self.nodes[cn].suspect_until.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let m = Membership::new(3, 1_000);
        assert!(m.is_serving(1));
        m.fail(1, 5_000);
        assert_eq!(m.state(1), NodeState::Failed);
        // Lease not expired: not yet detected.
        assert!(!m.detected_failed(1, 5_500));
        assert!(m.detected_failed(1, 6_000));
        assert_eq!(m.failed_at(10_000), vec![1]);
        m.begin_restart(1, 10_000);
        assert_eq!(m.state(1), NodeState::Restarting);
        assert!(!m.is_serving(1));
        let e0 = m.epoch(1);
        m.complete_restart(1, 11_000);
        assert!(m.is_serving(1));
        assert_eq!(m.epoch(1), e0 + 1);
    }

    #[test]
    fn suspicion_is_a_window_with_no_state_change() {
        let m = Membership::new(3, 1_000);
        assert!(!m.is_suspected(1, 0), "fresh nodes are unsuspected");
        let e0 = m.epoch(1);
        m.suspect(1, 2_000, 5_000);
        assert!(!m.is_suspected(1, 1_999));
        assert!(m.is_suspected(1, 2_000));
        assert!(m.is_suspected(1, 4_999));
        assert!(!m.is_suspected(1, 5_000), "window end rejoins silently");
        // Suspicion must not look like failure: state, serving flag,
        // epoch, and detection all unchanged (no lock rebuild path).
        assert_eq!(m.state(1), NodeState::Alive);
        assert!(m.is_serving(1));
        assert_eq!(m.epoch(1), e0);
        assert!(!m.detected_failed(1, 3_000));
        assert!(m.failed_at(3_000).is_empty());
        m.clear_suspicion(1);
        assert!(!m.is_suspected(1, 3_000));
    }

    #[test]
    fn suspicion_is_independent_of_failure() {
        let m = Membership::new(2, 100);
        m.suspect(0, 0, u64::MAX);
        m.fail(0, 50);
        assert!(m.is_suspected(0, 60));
        assert!(m.detected_failed(0, 150), "real failure still detected");
        m.begin_restart(0, 200);
        m.complete_restart(0, 300);
        assert!(m.is_suspected(0, 400), "restart does not clear suspicion");
        m.clear_suspicion(0);
        assert!(!m.is_suspected(0, 400));
    }

    #[test]
    fn multiple_failures_detected_independently() {
        let m = Membership::new(4, 100);
        m.fail(0, 0);
        m.fail(2, 50);
        assert_eq!(m.failed_at(100), vec![0]);
        assert_eq!(m.failed_at(150), vec![0, 2]);
    }
}
