//! Cross-layer integration tests: cluster-level invariants, artifact
//! pinning, ablation sanity, and serializability checking.

use std::sync::Arc;

use lotus::config::{Config, SystemKind};
use lotus::dm::{FaultInjector, FaultRule, VClock};
use lotus::sharding::key::LotusKey;
use lotus::sharding::transfer_shard;
use lotus::sim::{Cluster, CrashEvent, FaultScript, SuspicionWindow};
use lotus::txn::api::{RecordRef, TxnApi, TxnCtl};
use lotus::txn::coordinator::LotusCoordinator;
use lotus::txn::expect_ready;
use lotus::txn::scheduler::{FrameScheduler, LaneOutcome};
use lotus::workloads::smallbank::{CHECKING, SAVINGS};
use lotus::workloads::{RouteCtx, SmallBankWorkload, Workload, WorkloadKind};

fn tiny() -> Config {
    let mut cfg = Config::small();
    cfg.mn_capacity = 1 << 30; // TPC-C's 9 tables need headroom
    cfg.duration_ns = 4_000_000;
    cfg.scale.kvs_keys = 5_000;
    cfg.scale.smallbank_accounts = 5_000;
    cfg.scale.tatp_subscribers = 3_000;
    cfg.scale.tpcc_warehouses = 1;
    // CI matrix hook: pipeline_depth x coalesce_window_ns overrides.
    // Tests that assert a specific depth/window pin the fields after.
    cfg.apply_test_env();
    cfg
}


/// Audit: sum of all balances must equal the initial total plus the net
/// money committed deposits/withdrawals created/destroyed.
fn audit_books(cluster: &Cluster, wl: &SmallBankWorkload, n_accounts: u64, label: &str) {
    let expected =
        (SmallBankWorkload::initial_total(n_accounts) as i128 + wl.net_injected()) as u128;
    let mut total: u128 = 0;
    for acc in 0..n_accounts {
        for table in [SAVINGS, CHECKING] {
            let key = SmallBankWorkload::key(table, acc);
            let v = cluster.shared.tables[table as usize]
                .load_get(&cluster.shared.mns, 0, key)
                .unwrap_or_else(|| panic!("{label}: account {acc} table {table} lost"));
            total += u64::from_le_bytes(v[..8].try_into().unwrap()) as u128;
        }
    }
    assert_eq!(total, expected, "{label}: money created or destroyed");
}
/// SmallBank money audit under a full concurrent LOTUS benchmark: any
/// lost update, torn write, or isolation violation shows up as drift.
#[test]
fn smallbank_conserves_total_balance_under_lotus() {
    let cfg = tiny();
    let wl = Arc::new(SmallBankWorkload::new(cfg.scale.smallbank_accounts));
    let cluster = Cluster::build_with(&cfg, wl.clone() as Arc<dyn Workload>).unwrap();
    let report = cluster.run(SystemKind::Lotus).unwrap();
    assert!(report.commits > 100);
    audit_books(&cluster, &wl, cfg.scale.smallbank_accounts, "lotus");
}

/// The pipelined scheduler (`pipeline_depth > 1`) must preserve the
/// money audit too: sibling-frame conflicts abort lock-first, deferred
/// log clears ride other frames' doorbells, and no lane may leave a
/// held lock slot behind.
#[test]
fn smallbank_conserves_total_balance_under_pipelined_lotus() {
    let mut cfg = tiny();
    cfg.pipeline_depth = 4;
    cfg.coalesce_window_ns = 5_000;
    let wl = Arc::new(SmallBankWorkload::new(cfg.scale.smallbank_accounts));
    let cluster = Cluster::build_with(&cfg, wl.clone() as Arc<dyn Workload>).unwrap();
    let report = cluster.run(SystemKind::Lotus).unwrap();
    assert!(report.commits > 100);
    assert!(
        report.coalesced_ops > 0,
        "pipelined run should coalesce some doorbell ops"
    );
    audit_books(&cluster, &wl, cfg.scale.smallbank_accounts, "lotus-pipelined");
    let held: usize = cluster
        .shared
        .lock_services
        .iter()
        .map(|s| s.held_slots())
        .sum();
    assert_eq!(held, 0, "pipelined lanes left held lock slots");
}

/// The same audit for Motor and FORD (their locking is MN-side CAS).
/// Each system gets a fresh cluster: FORD is single-versioned (reads
/// cell 0 only) and cannot inherit a store whose latest versions live in
/// other cells after an MVCC run.
#[test]
fn smallbank_conserves_total_balance_under_baselines() {
    let cfg = tiny();
    for system in [SystemKind::Motor, SystemKind::Ford] {
        let wl = Arc::new(SmallBankWorkload::new(cfg.scale.smallbank_accounts));
        let cluster = Cluster::build_with(&cfg, wl.clone() as Arc<dyn Workload>).unwrap();
        let report = cluster.run(system).unwrap();
        assert!(report.commits > 50, "{}", system.name());
        audit_books(&cluster, &wl, cfg.scale.smallbank_accounts, system.name());
    }
}

/// Replicas converge: after a concurrent run, the primary and every
/// backup serve identical latest values.
#[test]
fn replicas_converge_after_concurrent_run() {
    let cfg = tiny();
    let cluster = Cluster::build(
        &cfg,
        WorkloadKind::Kvs {
            rw_pct: 80,
            skewed: true,
        },
    )
    .unwrap();
    cluster.run(SystemKind::Lotus).unwrap();
    let table = &cluster.shared.tables[0];
    for uid in (0..cfg.scale.kvs_keys).step_by(97) {
        let key = LotusKey::compose(uid, uid);
        let primary = table.load_get(&cluster.shared.mns, 0, key);
        for r in 1..table.replicas.len() {
            assert_eq!(
                primary,
                table.load_get(&cluster.shared.mns, r, key),
                "replica {r} diverged on key {uid}"
            );
        }
    }
}

/// Every `SystemKind` — LOTUS and all five baselines — completes a
/// SmallBank run through the shared `OpBatch`-planned protocol paths and
/// passes the money-conservation audit on its own fresh cluster.
#[test]
fn every_system_kind_runs_and_conserves_money() {
    let mut cfg = tiny();
    cfg.duration_ns = 2_000_000;
    for system in [
        SystemKind::Lotus,
        SystemKind::Motor,
        SystemKind::Ford,
        SystemKind::MotorFullRecord,
        SystemKind::MotorNoCas,
        SystemKind::FordNoCas,
        SystemKind::IdealLock,
    ] {
        let wl = Arc::new(SmallBankWorkload::new(cfg.scale.smallbank_accounts));
        let cluster = Cluster::build_with(&cfg, wl.clone() as Arc<dyn Workload>).unwrap();
        let report = cluster.run(system).unwrap();
        assert!(report.commits > 0, "{} made no progress", system.name());
        // The unsafe no-CAS modes deliberately skip mutual exclusion, so
        // the money audit only holds for the locking systems.
        if !matches!(system, SystemKind::MotorNoCas | SystemKind::FordNoCas) {
            audit_books(&cluster, &wl, cfg.scale.smallbank_accounts, system.name());
        }
    }
}

/// Every workload runs on every system without fatal errors.
#[test]
fn all_workloads_all_systems_smoke() {
    let mut cfg = tiny();
    cfg.duration_ns = 1_500_000;
    for kind in [
        WorkloadKind::Kvs {
            rw_pct: 50,
            skewed: false,
        },
        WorkloadKind::SmallBank,
        WorkloadKind::Tatp,
        WorkloadKind::Tpcc,
    ] {
        for system in [SystemKind::Lotus, SystemKind::Motor, SystemKind::Ford] {
            let cluster = Cluster::build(&cfg, kind).unwrap();
            let report = cluster.run(system).unwrap();
            assert!(
                report.commits > 0,
                "{} on {} made no progress",
                system.name(),
                kind.name()
            );
        }
    }
}

/// Ablation sanity (fig. 14 axes): every feature combination still passes
/// the money-conservation audit.
#[test]
fn ablation_configurations_stay_correct() {
    for (full, logv, lb, vt) in [
        (false, false, true, false),
        (true, false, true, false),
        (true, true, false, false),
        (true, true, true, true),
    ] {
        let mut cfg = tiny();
        cfg.duration_ns = 2_000_000;
        cfg.features.full_record_store = full;
        cfg.features.log_and_visible = logv;
        cfg.features.load_balancing = lb;
        cfg.features.vt_cache = vt;
        let wl = Arc::new(SmallBankWorkload::new(cfg.scale.smallbank_accounts));
        let cluster = Cluster::build_with(&cfg, wl.clone() as Arc<dyn Workload>).unwrap();
        cluster.run(SystemKind::Lotus).unwrap();
        audit_books(
            &cluster,
            &wl,
            cfg.scale.smallbank_accounts,
            &format!("ablation ({full},{logv},{lb},{vt})"),
        );
    }
}

/// Crash mid-run, then audit the books: recovery must preserve atomicity
/// (no half-applied transactions survive).
#[test]
fn crash_recovery_preserves_atomicity() {
    let mut cfg = tiny();
    cfg.n_cns = 3; // pinned: recovery needs surviving CNs
    cfg.duration_ns = 30_000_000;
    cfg.timeline_interval_ns = 1_000_000;
    let wl = Arc::new(SmallBankWorkload::new(cfg.scale.smallbank_accounts));
    let cluster = Cluster::build_with(&cfg, wl.clone() as Arc<dyn Workload>).unwrap();
    let report = cluster
        .run_with_events(
            SystemKind::Lotus,
            &[CrashEvent {
                at_ns: 10_000_000,
                cns: vec![0],
            }],
        )
        .unwrap();
    assert!(report.commits > 100);
    audit_books(&cluster, &wl, cfg.scale.smallbank_accounts, "crash-recovery");
    let held: usize = cluster
        .shared
        .lock_services
        .iter()
        .map(|s| s.held_slots())
        .sum();
    assert_eq!(held, 0);
}

/// ISSUE 3 step-machine audit: a depth-4 pipelined run with a mid-run CN
/// crash must conserve money and leave zero held lock slots — staged
/// (posted-but-unrung) plans die with the crashed CN, recovery completes
/// or rolls back from the commit logs, and the surviving lanes' merged
/// doorbell rings must not leak or duplicate any write.
#[test]
fn pipelined_crash_recovery_conserves_money_and_locks() {
    let mut cfg = tiny();
    cfg.n_cns = 3; // pinned: recovery needs surviving CNs
    cfg.duration_ns = 30_000_000;
    cfg.pipeline_depth = 4;
    cfg.coalesce_window_ns = 5_000;
    let wl = Arc::new(SmallBankWorkload::new(cfg.scale.smallbank_accounts));
    let cluster = Cluster::build_with(&cfg, wl.clone() as Arc<dyn Workload>).unwrap();
    let report = cluster
        .run_with_events(
            SystemKind::Lotus,
            &[CrashEvent {
                at_ns: 10_000_000,
                cns: vec![0],
            }],
        )
        .unwrap();
    assert!(report.commits > 100);
    assert!(
        report.overlap_rings > 0,
        "depth-4 lanes should overlap staged plans even across a crash"
    );
    audit_books(
        &cluster,
        &wl,
        cfg.scale.smallbank_accounts,
        "pipelined-crash-recovery",
    );
    let held: usize = cluster
        .shared
        .lock_services
        .iter()
        .map(|s| s.held_slots())
        .sum();
    assert_eq!(held, 0, "crash + recovery left held lock slots");
    for (i, nic) in cluster.shared.cn_nics.iter().enumerate() {
        assert_eq!(
            nic.posted_wqes(),
            0,
            "cn{i}: staged WQEs neither rung nor discarded by the crash"
        );
    }
}

/// ISSUE 7 tentpole acceptance: a crash storm *plus* a lossy fabric (1%
/// of messages dropped for the whole run, retries enabled) must still
/// conserve money and strand zero lock slots — a lost lock message parks
/// its lane in capped exponential backoff and reissues, exhausted
/// retries abort cleanly with every acquired lock released, and recovery
/// drops the crashed CN's ephemeral locks.
#[test]
fn chaos_storm_with_lossy_fabric_conserves_money_and_locks() {
    let mut cfg = tiny();
    cfg.n_cns = 3; // pinned: recovery needs surviving CNs
    cfg.duration_ns = 30_000_000;
    cfg.pipeline_depth = 4;
    cfg.coalesce_window_ns = 5_000;
    cfg.rpc_max_retries = 3; // pinned: the retry path must be exercised
    let wl = Arc::new(SmallBankWorkload::new(cfg.scale.smallbank_accounts));
    let cluster = Cluster::build_with(&cfg, wl.clone() as Arc<dyn Workload>).unwrap();
    let script = FaultScript {
        crashes: vec![CrashEvent {
            at_ns: 10_000_000,
            cns: vec![0],
        }],
        faults: Some(Arc::new(
            FaultInjector::new(cfg.seed).rule(FaultRule::drop(10)),
        )),
        suspicions: vec![],
    };
    let report = cluster.run_with_faults(SystemKind::Lotus, &script).unwrap();
    assert!(report.commits > 100);
    assert!(
        report.rpc_dropped > 0,
        "the lossy fabric never lost a message"
    );
    assert!(
        report.rpc_retries > 0,
        "no lost lock message was ever retried"
    );
    audit_books(&cluster, &wl, cfg.scale.smallbank_accounts, "chaos-storm");
    let held: usize = cluster
        .shared
        .lock_services
        .iter()
        .map(|s| s.held_slots())
        .sum();
    assert_eq!(held, 0, "chaos storm + message loss left held lock slots");
}

/// ISSUE 7 equivalence anchor: an installed-but-empty `FaultInjector` is
/// byte-inert — a depth-1 multi-CN run under it matches a plain run of
/// the same cluster config field-for-field (`RunReport` equality), even
/// with the retry machinery armed (it must never fire).
#[test]
fn zero_fault_injector_is_byte_inert() {
    let mut cfg = tiny();
    cfg.n_cns = 3; // pinned: remote lock RPCs must flow through the injector hook
    cfg.pipeline_depth = 1;
    cfg.rpc_max_retries = 3; // armed, but with no faults it must never fire
    cfg.balance_interval_ns = 100_000_000; // pinned: armed rebalance races the planner
    let run = |faults: Option<Arc<FaultInjector>>| {
        let cluster = Cluster::build(&cfg, WorkloadKind::SmallBank).unwrap();
        let script = FaultScript {
            crashes: vec![],
            faults,
            suspicions: vec![],
        };
        cluster.run_with_faults(SystemKind::Lotus, &script).unwrap()
    };
    let plain = run(None);
    let inert = run(Some(Arc::new(FaultInjector::new(cfg.seed))));
    assert!(plain.commits > 100);
    assert!(plain.rpc_messages > 0, "the run must exercise the fabric");
    assert_eq!(inert.rpc_dropped, 0);
    assert_eq!(inert.rpc_retries, 0);
    // PR 8: the injector is installed on the doorbell plane too — with
    // no doorbell rules it must stay silent there as well.
    assert_eq!(inert.mn_op_faults, 0);
    assert_eq!(inert.torn_batches, 0);
    assert_eq!(plain, inert, "an empty fault injector perturbed the run");
}

/// PR 8 equivalence anchor: the doorbell-plane fault hook is byte-inert
/// when the installed injector is empty — a depth-4, 3-CN, 2-MN run,
/// where every commit rides coalesced doorbell rings through the hook,
/// matches the plain run field-for-field. RPC-plane-only rules must be
/// equally invisible to the doorbell plane.
#[test]
fn empty_injector_leaves_the_doorbell_plane_byte_inert_at_depth_4() {
    let mut cfg = tiny();
    cfg.n_cns = 3; // pinned with 2 MNs: rings fan out across MNs
    cfg.pipeline_depth = 4;
    cfg.coalesce_window_ns = 5_000;
    cfg.adaptive_coalescing = false;
    cfg.balance_interval_ns = 100_000_000; // pinned: armed rebalance races the planner
    let run = |faults: Option<Arc<FaultInjector>>| {
        let cluster = Cluster::build(&cfg, WorkloadKind::SmallBank).unwrap();
        let script = FaultScript {
            crashes: vec![],
            faults,
            suspicions: vec![],
        };
        cluster.run_with_faults(SystemKind::Lotus, &script).unwrap()
    };
    let plain = run(None);
    let inert = run(Some(Arc::new(FaultInjector::new(cfg.seed))));
    assert!(plain.commits > 100);
    assert!(plain.doorbells > 0, "the run must ring doorbells");
    assert_eq!(plain.mn_op_faults, 0);
    assert_eq!(plain.torn_batches, 0);
    assert_eq!(plain, inert, "an empty injector perturbed the doorbell plane");
    // An injector with RPC-plane rules that can never fire (0 permille)
    // still exercises the rule-matching path per ring — and must still
    // change nothing.
    let rpc_only = run(Some(Arc::new(
        FaultInjector::new(cfg.seed).rule(FaultRule::gray_slow(4, 0)),
    )));
    assert_eq!(plain, rpc_only, "an RPC-plane rule leaked into the doorbell plane");
}

/// ISSUE 9 equivalence anchor: epoch-batched clock publication is
/// byte-inert at depth 1 — throttling the cross-core clock store changes
/// *when* peers observe a coordinator's progress (wall-clock), never the
/// conservative lower bound they gate on (virtual time), so a 3-CN run
/// with `gate_publish_ns` raised matches the per-bump run
/// field-for-field.
#[test]
fn epoch_batched_clock_publication_is_byte_inert_at_depth_1() {
    let run = |publish_ns: u64| {
        let mut cfg = tiny();
        cfg.n_cns = 3; // pinned: cross-coordinator skew must be live
        cfg.pipeline_depth = 1;
        cfg.gate_publish_ns = publish_ns; // after apply_test_env: this axis is the test
        cfg.balance_interval_ns = 100_000_000; // pinned: armed rebalance races the planner
        let cluster = Cluster::build(&cfg, WorkloadKind::SmallBank).unwrap();
        cluster.run(SystemKind::Lotus).unwrap()
    };
    let per_bump = run(0);
    let batched = run(2_500);
    assert!(per_bump.commits > 100);
    assert_eq!(
        per_bump, batched,
        "epoch-batched publication perturbed a depth-1 run"
    );
}

/// ISSUE 9 equivalence anchor, pipelined flavor: the same inertness must
/// hold at depth 4 with coalescing live, where lanes overlap and the
/// gate is consulted on every doorbell ring.
#[test]
fn epoch_batched_clock_publication_is_byte_inert_at_depth_4() {
    let run = |publish_ns: u64| {
        let mut cfg = tiny();
        cfg.n_cns = 3; // pinned with 2 MNs: rings fan out across MNs
        cfg.pipeline_depth = 4;
        cfg.coalesce_window_ns = 5_000;
        cfg.adaptive_coalescing = false;
        cfg.gate_publish_ns = publish_ns;
        cfg.balance_interval_ns = 100_000_000; // pinned: armed rebalance races the planner
        let cluster = Cluster::build(&cfg, WorkloadKind::SmallBank).unwrap();
        cluster.run(SystemKind::Lotus).unwrap()
    };
    let per_bump = run(0);
    let batched = run(2_500);
    assert!(per_bump.commits > 100);
    assert!(per_bump.doorbells > 0, "the run must ring doorbells");
    assert_eq!(
        per_bump, batched,
        "epoch-batched publication perturbed a depth-4 run"
    );
}

/// PR 8: a gray MN spell mid-run — an unreachable window followed by a
/// torn-doorbell window, no crash — must cost only aborts and retries:
/// no stranded locks, no money drift, and every sealed commit is kept
/// (the commit phase rolls `write_visible` forward through the faults).
#[test]
fn gray_mn_windows_abort_cleanly_and_conserve_money() {
    let mut cfg = tiny();
    cfg.n_cns = 3;
    cfg.pipeline_depth = 4;
    let wl = Arc::new(SmallBankWorkload::new(cfg.scale.smallbank_accounts));
    let cluster = Cluster::build_with(&cfg, wl.clone() as Arc<dyn Workload>).unwrap();
    let script = FaultScript {
        crashes: vec![],
        faults: Some(Arc::new(
            FaultInjector::new(cfg.seed)
                .rule(FaultRule::mn_unreachable(0).window(1_000_000, 1_300_000))
                .rule(FaultRule::torn_batch(300).window(2_000_000, 2_300_000)),
        )),
        suspicions: vec![],
    };
    let report = cluster.run_with_faults(SystemKind::Lotus, &script).unwrap();
    assert!(report.commits > 100, "commits={}", report.commits);
    assert!(report.mn_op_faults > 0, "the windows must hit some rings");
    assert!(report.torn_batches > 0, "the torn window must tear some rings");
    audit_books(&cluster, &wl, cfg.scale.smallbank_accounts, "gray-mn");
    let held: usize = cluster
        .shared
        .lock_services
        .iter()
        .map(|s| s.held_slots())
        .sum();
    assert_eq!(held, 0, "a doorbell fault stranded a lock slot");
}

/// ISSUE 7 determinism acceptance: the same seed and the same
/// `FaultScript` — crash storm, sustained loss, a gray window, and a
/// suspicion window — replay to an identical `RunReport` twice in a row,
/// field for field. Every fault decision is a pure function of the
/// injector seed and the message coordinates, never of host entropy.
#[test]
fn same_seed_same_fault_script_is_deterministic() {
    let mut cfg = tiny();
    cfg.n_cns = 3; // pinned: the script names CNs 0 and 2
    cfg.duration_ns = 20_000_000;
    cfg.pipeline_depth = 4;
    cfg.coalesce_window_ns = 5_000;
    cfg.rpc_max_retries = 2;
    cfg.balance_interval_ns = 100_000_000; // pinned: armed rebalance races the planner
    let script = || FaultScript {
        crashes: vec![CrashEvent {
            at_ns: 6_000_000,
            cns: vec![0],
        }],
        faults: Some(Arc::new(
            FaultInjector::new(cfg.seed)
                .rule(FaultRule::drop(20).window(6_000_000, u64::MAX))
                .rule(FaultRule::gray_slow(4, 300).window(6_000_000, 12_000_000)),
        )),
        suspicions: vec![SuspicionWindow {
            cn: 2,
            from_ns: 8_000_000,
            until_ns: 9_000_000,
        }],
    };
    let run = || {
        let cluster = Cluster::build(&cfg, WorkloadKind::SmallBank).unwrap();
        cluster.run_with_faults(SystemKind::Lotus, &script()).unwrap()
    };
    let a = run();
    let b = run();
    assert!(a.commits > 100);
    assert!(a.rpc_dropped > 0, "the storm script never dropped a message");
    assert_eq!(
        a, b,
        "same seed + same fault script must replay byte-identically"
    );
}

/// Snapshot isolation commits more under read-write contention than SR
/// (it skips read locks), and both preserve the write-write audit.
#[test]
fn si_outperforms_sr_under_contention() {
    let mut sr = tiny();
    sr.duration_ns = 3_000_000;
    sr.scale.smallbank_accounts = 200; // hot
    let mut si = sr.clone();
    si.isolation = lotus::txn::api::Isolation::SnapshotIsolation;
    let c_sr = Cluster::build(&sr, WorkloadKind::SmallBank).unwrap();
    let c_si = Cluster::build(&si, WorkloadKind::SmallBank).unwrap();
    let r_sr = c_sr.run(SystemKind::Lotus).unwrap();
    let r_si = c_si.run(SystemKind::Lotus).unwrap();
    assert!(
        r_si.commits as f64 >= r_sr.commits as f64 * 0.9,
        "SI ({}) should not trail SR ({}) meaningfully",
        r_si.commits,
        r_sr.commits
    );
}

/// ISSUE 4 resumption fairness: with the ready-queue scheduler, every
/// lane parked by a merged doorbell ring is resumed in completion-clock
/// order — no lane starves behind an "innermost" sibling the way the old
/// stack-unwind design forced — and `resumed_rings` is visible in the
/// accounting. Depth 1, by contrast, never stages or resumes anything
/// and stays byte-identical to the depth-0 legacy shell.
#[test]
fn depth4_lanes_resume_in_completion_clock_order() {
    let mut cfg = tiny();
    cfg.n_cns = 1;
    cfg.coordinators_per_cn = 1;
    cfg.pipeline_depth = 4;
    cfg.coalesce_window_ns = 5_000;
    cfg.scale.smallbank_accounts = 2_000;
    cfg.balance_interval_ns = 100_000_000; // pinned: armed rebalance races the planner
    let cluster = Cluster::build(&cfg, WorkloadKind::SmallBank).unwrap();
    let workload = cluster.workload.clone();
    let mut sched = FrameScheduler::new(cluster.shared.clone(), 0, 0, 0);
    sched.enable_resume_trace();
    let route = RouteCtx {
        router: &cluster.shared.router,
        cn: 0,
        hybrid: false,
    };
    let mut outcomes: Vec<LaneOutcome> = Vec::new();
    while outcomes.len() < 400 {
        sched.step(&workload, &route, &mut outcomes).unwrap();
    }
    sched.finish(&mut outcomes).unwrap();

    // No starvation: every lane completed transactions.
    for lane in 0..4 {
        let n = outcomes.iter().filter(|o| o.lane == lane).count();
        assert!(n > 0, "lane {lane} never completed a transaction");
    }
    // Rings resumed parked lanes, and some ring resumed several.
    let trace = sched.resume_trace();
    assert!(!trace.is_empty(), "no parked lane was ever resumed");
    assert!(
        cluster.shared.cn_nics[0].resumed_rings() > 0,
        "resumed_rings accounting missed the resumes"
    );
    let max_ring = trace.iter().map(|&(r, _, _)| r).max().unwrap();
    let mut multi_resume = false;
    for ring in 1..=max_ring {
        let resumes: Vec<_> = trace.iter().filter(|&&(r, _, _)| r == ring).collect();
        if resumes.len() >= 2 {
            multi_resume = true;
        }
        // Completion-clock order within a ring: the lane that finished
        // earlier is polled earlier.
        for pair in resumes.windows(2) {
            assert!(
                pair[0].2 <= pair[1].2,
                "ring {ring}: lane {} (done {}) resumed before lane {} (done {})",
                pair[0].1,
                pair[0].2,
                pair[1].1,
                pair[1].2
            );
        }
    }
    assert!(
        multi_resume,
        "no ring ever re-enqueued more than one parked lane"
    );

    // Depth 1: zero staging / zero resumes, byte-identical to the
    // depth-0 legacy shell.
    let run = |depth: usize| {
        let mut c = cfg.clone();
        c.pipeline_depth = depth;
        c.duration_ns = 2_000_000;
        let cl = Cluster::build(&c, WorkloadKind::SmallBank).unwrap();
        cl.run(SystemKind::Lotus).unwrap()
    };
    let legacy = run(0);
    let pipe1 = run(1);
    assert_eq!(legacy.commits, pipe1.commits);
    assert_eq!(legacy.aborts, pipe1.aborts);
    assert_eq!(legacy.p50_ns, pipe1.p50_ns);
    assert_eq!(legacy.p99_ns, pipe1.p99_ns);
    assert_eq!(legacy.doorbells, pipe1.doorbells);
    assert_eq!(legacy.doorbell_ops, pipe1.doorbell_ops);
    assert_eq!(pipe1.staged_plans, 0, "depth 1 must not stage");
    assert_eq!(pipe1.resumed_rings, 0, "depth 1 must not resume");
}

/// ISSUE 4 regression (satellite): `coalesce_window_ns = 0` with
/// `pipeline_depth >= 2` must run without a coalescer — deferred
/// fire-and-forget plans issue immediately rather than parking until
/// `finish()` — and still conserve money with the posted gauge drained.
#[test]
fn window_zero_pipelined_run_conserves_money() {
    let mut cfg = tiny();
    cfg.pipeline_depth = 4;
    cfg.coalesce_window_ns = 0;
    let wl = Arc::new(SmallBankWorkload::new(cfg.scale.smallbank_accounts));
    let cluster = Cluster::build_with(&cfg, wl.clone() as Arc<dyn Workload>).unwrap();
    let report = cluster.run(SystemKind::Lotus).unwrap();
    assert!(report.commits > 100);
    assert_eq!(report.staged_plans, 0, "window 0 must disable staging");
    assert_eq!(report.resumed_rings, 0);
    assert_eq!(report.coalesced_ops, 0, "window 0 must disable coalescing");
    audit_books(&cluster, &wl, cfg.scale.smallbank_accounts, "window-zero");
    for (i, nic) in cluster.shared.cn_nics.iter().enumerate() {
        assert_eq!(nic.posted_wqes(), 0, "cn{i}: posted gauge not drained");
    }
    let held: usize = cluster
        .shared
        .lock_services
        .iter()
        .map(|s| s.held_slots())
        .sum();
    assert_eq!(held, 0);
}

/// ISSUE 5 tentpole acceptance: with multiple CNs and `pipeline_depth =
/// 4`, sibling lanes' remote-lock batches to the same destination CN
/// share RPC messages — the run reports `coalesced_rpc_reqs > 0` and a
/// strictly lower `rpc_messages_per_commit()` than the same cluster at
/// depth 1 (where every remote batch sends its own message and every
/// remote unlock its own fire-and-forget send).
#[test]
fn depth4_remote_lock_rpcs_coalesce_across_lanes() {
    let mut cfg = tiny();
    cfg.n_cns = 3; // pinned: the RPC plane needs remote lock owners
    cfg.coalesce_window_ns = 5_000;
    let run = |depth: usize| {
        let mut c = cfg.clone();
        c.pipeline_depth = depth;
        let cluster = Cluster::build(&c, WorkloadKind::SmallBank).unwrap();
        cluster.run(SystemKind::Lotus).unwrap()
    };
    let d1 = run(1);
    let d4 = run(4);
    assert!(d4.commits > 100, "commits={}", d4.commits);
    assert!(
        d1.rpc_messages > 0,
        "multi-CN SmallBank must exercise remote lock RPCs"
    );
    assert_eq!(
        d1.coalesced_rpc_reqs, 0,
        "depth 1 must not merge RPC messages"
    );
    assert!(
        d4.coalesced_rpc_reqs > 0,
        "no sibling lock batch or unlock ever shared an RPC message"
    );
    assert!(
        d4.rpc_messages_per_commit() < d1.rpc_messages_per_commit(),
        "RPC coalescing must cut messages/txn: d4 {:.3} vs d1 {:.3}",
        d4.rpc_messages_per_commit(),
        d1.rpc_messages_per_commit()
    );
    assert!(
        d4.reqs_per_rpc_message() > d1.reqs_per_rpc_message(),
        "merged messages must carry more requests each: d4 {:.3} vs d1 {:.3}",
        d4.reqs_per_rpc_message(),
        d1.reqs_per_rpc_message()
    );
}

/// ISSUE 5 equivalence anchor: with remote keys in play, the depth-1
/// scheduler routes every lock RPC through the (new) staged issue-point
/// code — but with no siblings nothing stages, so every message is the
/// classic synchronous call and the per-transaction outcomes, clocks and
/// fabric counters are byte-identical to the depth-0 legacy shell.
#[test]
fn depth1_remote_rpcs_stay_direct_and_match_depth0() {
    let mut cfg = tiny();
    cfg.n_cns = 2; // pinned: remote keys, single driven coordinator
    cfg.coordinators_per_cn = 1;
    cfg.pipeline_depth = 1;
    cfg.coalesce_window_ns = 5_000;
    cfg.scale.smallbank_accounts = 2_000;
    const N: usize = 200;

    // Depth-0 legacy shell on its own cluster.
    let a = Cluster::build(&cfg, WorkloadKind::SmallBank).unwrap();
    let mut co = LotusCoordinator::new(a.shared.clone(), 0, 0, 0);
    let route = RouteCtx {
        router: &a.shared.router,
        cn: 0,
        hybrid: false,
    };
    let mut seq: Vec<(bool, u64, u64)> = Vec::with_capacity(N);
    for _ in 0..N {
        let t0 = co.now();
        let r = expect_ready(a.workload.run_one(&mut co, &route));
        seq.push((r.is_ok(), t0, co.now()));
    }

    // Depth-1 scheduler on a fresh identical cluster.
    let b = Cluster::build(&cfg, WorkloadKind::SmallBank).unwrap();
    let workload = b.workload.clone();
    let mut sched = FrameScheduler::new(b.shared.clone(), 0, 0, 0);
    let route_b = RouteCtx {
        router: &b.shared.router,
        cn: 0,
        hybrid: false,
    };
    let mut outcomes: Vec<LaneOutcome> = Vec::new();
    while outcomes.len() < N {
        sched.step(&workload, &route_b, &mut outcomes).unwrap();
    }

    assert!(
        b.shared.cn_nics[0].rpc_messages() > 0,
        "the run must have sent remote lock RPCs"
    );
    for (i, o) in outcomes.iter().take(N).enumerate() {
        let (ok, t0, t1) = seq[i];
        assert_eq!(o.result.is_ok(), ok, "txn {i}: outcome differs");
        assert_eq!(o.t_begin, t0, "txn {i}: begin clock differs");
        assert_eq!(o.t_end, t1, "txn {i}: completion clock differs");
    }
    // Byte-identical fabric accounting on both planes, zero staging.
    let (na, nb) = (&a.shared.cn_nics[0], &b.shared.cn_nics[0]);
    assert_eq!(na.doorbells(), nb.doorbells(), "doorbells differ");
    assert_eq!(na.doorbell_ops(), nb.doorbell_ops(), "doorbell ops differ");
    assert_eq!(na.rpc_messages(), nb.rpc_messages(), "rpc messages differ");
    assert_eq!(na.rpc_reqs(), nb.rpc_reqs(), "rpc reqs differ");
    assert_eq!(nb.staged_plans(), 0, "depth 1 must not stage doorbell plans");
    assert_eq!(nb.coalesced_rpc_reqs(), 0, "depth 1 must not merge RPCs");
    assert_eq!(nb.lock_waits(), 0, "depth 1 has no siblings to wait on");
}

/// ISSUE 6 tentpole acceptance — the saturation study. Many CNs route a
/// skewed (low-locality) lock workload at one hot destination CN. A
/// fixed window faces a dilemma: too narrow and the hot handler queue
/// drowns in per-message overhead (messages/commit stays high); too wide
/// and every staged plan eats the full window in latency (p99 balloons).
/// The per-destination congestion controller must beat the narrow
/// window on messages/commit AND the wide window on p99 in the same run,
/// by widening only the hot destination's window and holding the rest
/// near direct issue.
#[test]
fn adaptive_coalescing_beats_both_fixed_windows_under_hot_destination() {
    let mut cfg = tiny();
    cfg.n_cns = 6; // pinned: many sources, skew concentrates on few owners
    cfg.coordinators_per_cn = 2;
    cfg.pipeline_depth = 4;
    cfg.features.load_balancing = false; // keep the hot spot hot
    cfg.drift_interval_ns = 0; // pinned: the hot spot must not move either
    cfg.scale.kvs_keys = 2_000;
    let run = |window: u64, adaptive: bool| {
        let mut c = cfg.clone();
        c.coalesce_window_ns = window;
        c.adaptive_coalescing = adaptive;
        let cluster = Cluster::build(
            &c,
            WorkloadKind::Kvs {
                rw_pct: 100,
                skewed: true,
            },
        )
        .unwrap();
        cluster.run(SystemKind::Lotus).unwrap()
    };
    let narrow = run(500, false);
    let wide = run(40_000, false);
    let adaptive = run(5_000, true);
    for (r, label) in [(&narrow, "narrow"), (&wide, "wide"), (&adaptive, "adaptive")] {
        assert!(r.commits > 100, "{label}: commits={}", r.commits);
        assert!(r.rpc_messages > 0, "{label}: no remote lock traffic");
    }
    assert!(
        adaptive.handler_chunks > 0,
        "the handler queue model must have measured waits"
    );
    assert!(
        adaptive.rpc_messages_per_commit() < narrow.rpc_messages_per_commit(),
        "adaptive must out-coalesce the narrow window: {:.3} vs {:.3}",
        adaptive.rpc_messages_per_commit(),
        narrow.rpc_messages_per_commit()
    );
    assert!(
        adaptive.p99_ns < wide.p99_ns,
        "adaptive must undercut the wide window's tail: {} vs {}",
        adaptive.p99_ns,
        wide.p99_ns
    );
}

/// ISSUE 6 equivalence anchor: `adaptive_coalescing = true` changes
/// nothing at depth 1 — no coalescer exists, the controller is never
/// consulted, and the per-transaction outcomes, clocks and fabric
/// counters stay byte-identical to the depth-0 legacy shell.
#[test]
fn depth1_with_adaptive_coalescing_matches_depth0_exactly() {
    let mut cfg = tiny();
    cfg.n_cns = 2; // pinned: remote keys, single driven coordinator
    cfg.coordinators_per_cn = 1;
    cfg.pipeline_depth = 1;
    cfg.coalesce_window_ns = 5_000;
    cfg.adaptive_coalescing = true;
    cfg.scale.smallbank_accounts = 2_000;
    const N: usize = 200;

    let a = Cluster::build(&cfg, WorkloadKind::SmallBank).unwrap();
    let mut co = LotusCoordinator::new(a.shared.clone(), 0, 0, 0);
    let route = RouteCtx {
        router: &a.shared.router,
        cn: 0,
        hybrid: false,
    };
    let mut seq: Vec<(bool, u64, u64)> = Vec::with_capacity(N);
    for _ in 0..N {
        let t0 = co.now();
        let r = expect_ready(a.workload.run_one(&mut co, &route));
        seq.push((r.is_ok(), t0, co.now()));
    }

    let b = Cluster::build(&cfg, WorkloadKind::SmallBank).unwrap();
    let workload = b.workload.clone();
    let mut sched = FrameScheduler::new(b.shared.clone(), 0, 0, 0);
    let route_b = RouteCtx {
        router: &b.shared.router,
        cn: 0,
        hybrid: false,
    };
    let mut outcomes: Vec<LaneOutcome> = Vec::new();
    while outcomes.len() < N {
        sched.step(&workload, &route_b, &mut outcomes).unwrap();
    }

    for (i, o) in outcomes.iter().take(N).enumerate() {
        let (ok, t0, t1) = seq[i];
        assert_eq!(o.result.is_ok(), ok, "txn {i}: outcome differs");
        assert_eq!(o.t_begin, t0, "txn {i}: begin clock differs");
        assert_eq!(o.t_end, t1, "txn {i}: completion clock differs");
    }
    let (na, nb) = (&a.shared.cn_nics[0], &b.shared.cn_nics[0]);
    assert_eq!(na.doorbells(), nb.doorbells(), "doorbells differ");
    assert_eq!(na.doorbell_ops(), nb.doorbell_ops(), "doorbell ops differ");
    assert_eq!(na.rpc_messages(), nb.rpc_messages(), "rpc messages differ");
    assert_eq!(na.rpc_reqs(), nb.rpc_reqs(), "rpc reqs differ");
    assert_eq!(nb.staged_plans(), 0, "depth 1 must not stage doorbell plans");
    assert_eq!(nb.coalesced_rpc_reqs(), 0, "depth 1 must not merge RPCs");
}

/// The money audit holds with the congestion controller steering both
/// planes' windows: adaptive merge timing must not reorder, drop or
/// duplicate any write or unlock.
#[test]
fn smallbank_conserves_money_with_adaptive_coalescing() {
    let mut cfg = tiny();
    cfg.n_cns = 3; // pinned: remote lock owners exercise the RPC plane
    cfg.pipeline_depth = 4;
    cfg.coalesce_window_ns = 5_000;
    cfg.adaptive_coalescing = true;
    let wl = Arc::new(SmallBankWorkload::new(cfg.scale.smallbank_accounts));
    let cluster = Cluster::build_with(&cfg, wl.clone() as Arc<dyn Workload>).unwrap();
    let report = cluster.run(SystemKind::Lotus).unwrap();
    assert!(report.commits > 100);
    audit_books(&cluster, &wl, cfg.scale.smallbank_accounts, "lotus-adaptive");
    let held: usize = cluster
        .shared
        .lock_services
        .iter()
        .map(|s| s.held_slots())
        .sum();
    assert_eq!(held, 0, "adaptive coalescing left held lock slots");
}

/// Direct API use against a shared cluster (the library path a downstream
/// user takes, mirroring the quickstart).
#[test]
fn manual_transactions_interleave_with_benchmark_state() {
    let mut cfg = tiny();
    cfg.n_cns = 3; // pinned: the manual coordinator sits on CN 1
    let cluster = Cluster::build(
        &cfg,
        WorkloadKind::Kvs {
            rw_pct: 50,
            skewed: false,
        },
    )
    .unwrap();
    let shared: Arc<_> = cluster.shared.clone();
    let mut co = LotusCoordinator::new(shared, 1, 0, 2);
    let r = RecordRef::new(0, LotusKey::compose(7, 7));
    co.begin(false);
    co.txn().add_rw(r);
    co.txn().execute().unwrap();
    co.txn().stage_write(r, b"manual".to_vec());
    co.txn().commit().unwrap();
    co.begin(true);
    co.txn().add_ro(r);
    co.txn().execute().unwrap();
    assert_eq!(co.txn().value(r).unwrap(), b"manual");
}

/// ISSUE 10 tentpole acceptance: under a *drifting* hot spot, the
/// periodic balance tick must chase lock ownership of the hot shards
/// and strictly beat static placement on committed throughput at depth
/// 4 across 3 CNs — while the post-move dip recovers and no lock slot
/// is stranded.
#[test]
fn rebalancing_chases_a_drifting_hot_spot_and_beats_static() {
    let run = |balance_interval_ns: u64| {
        let mut cfg = tiny();
        cfg.n_cns = 3; // pinned: a hot CN needs cold peers to shed to
        cfg.coordinators_per_cn = 2;
        cfg.pipeline_depth = 4;
        cfg.coalesce_window_ns = 5_000;
        cfg.duration_ns = 24_000_000;
        cfg.timeline_interval_ns = 1_000_000;
        cfg.scale.kvs_keys = 50_000;
        cfg.drift_interval_ns = 6_000_000; // pinned: the hot spot must move
        cfg.flash_crowd_at_ns = 0;
        cfg.balance_interval_ns = balance_interval_ns;
        cfg.max_moves_per_tick = 1;
        let cluster = Cluster::build(
            &cfg,
            WorkloadKind::Kvs {
                rw_pct: 100,
                skewed: true,
            },
        )
        .unwrap();
        let report = cluster.run(SystemKind::Lotus).unwrap();
        let held: usize = cluster
            .shared
            .lock_services
            .iter()
            .map(|s| s.held_slots())
            .sum();
        assert_eq!(held, 0, "balance={balance_interval_ns}: stranded lock slots");
        report
    };
    let reb = run(1_000_000); // 1 ms balance tick
    let sta = run(0); // tick disabled: static placement
    assert!(
        reb.reshard_moves > 0,
        "a moving hot spot must trigger shard moves"
    );
    assert!(
        reb.reshard_interruption_ns > 0,
        "moves must charge a lock-service interruption"
    );
    assert_eq!(sta.reshard_moves, 0, "static placement must never move");
    assert_eq!(sta.wrong_owner_bounces, 0, "a static map is never stale");
    assert!(
        reb.commits > sta.commits,
        "chasing the hot spot must beat static placement ({} vs {})",
        reb.commits,
        sta.commits
    );
    // Dip-and-recovery: after the moves settle, the tail of the curve
    // sits at or above the worst post-warmup bucket.
    let t = &reb.timeline;
    assert!(t.len() >= 12, "timeline too short: {} buckets", t.len());
    let dip = t[4..].iter().copied().min().unwrap();
    let tail = t[t.len() - 4..].iter().sum::<u64>() / 4;
    assert!(
        tail >= dip,
        "throughput must recover after the post-move dip (dip {dip}, tail {tail})"
    );
}

/// ISSUE 10 satellite: an *armed* balance tick that plans zero moves is
/// byte-inert. Under uniform load the overload predicate (latency 1.5x
/// over the cluster mean for three straight sealed intervals) never
/// trips, so sealing/draining/planning stay host-side: the RunReport is
/// identical to a tick-disabled run, at depth 1 and at depth 4.
#[test]
fn armed_tick_with_zero_planned_moves_is_byte_inert() {
    for depth in [1usize, 4] {
        let run = |balance_interval_ns: u64| {
            let mut cfg = tiny();
            cfg.n_cns = 3; // pinned: symmetric CNs keep the predicate cold
            cfg.pipeline_depth = depth;
            cfg.coalesce_window_ns = 5_000;
            cfg.drift_interval_ns = 0; // pinned: uniform load stays uniform
            cfg.balance_interval_ns = balance_interval_ns;
            let cluster = Cluster::build(
                &cfg,
                WorkloadKind::Kvs {
                    rw_pct: 50,
                    skewed: false,
                },
            )
            .unwrap();
            cluster.run(SystemKind::Lotus).unwrap()
        };
        let armed = run(500_000);
        let off = run(0);
        assert_eq!(
            armed.reshard_moves, 0,
            "depth {depth}: uniform load must plan no moves"
        );
        assert_eq!(
            armed, off,
            "depth {depth}: an idle balance tick perturbed the run"
        );
    }
}

/// ISSUE 10 satellite: a lane whose lock request lands on a CN that just
/// lost the shard must bounce with `WrongShardOwner`, park, re-resolve
/// against the fresh map, and retry — not abort. Every shard carrying
/// the SmallBank working set ping-pongs between both CNs while a
/// depth-4 scheduler is mid-flight, so staged owner resolutions go stale
/// wholesale; the bounces surface on the NIC counter, bounced lanes
/// still commit, and the books balance.
#[test]
fn wrong_owner_bounce_parks_and_retries_against_fresh_map() {
    let mut cfg = tiny();
    cfg.n_cns = 2; // pinned: ping-pong partner for every shard
    cfg.coordinators_per_cn = 1;
    cfg.pipeline_depth = 4;
    cfg.coalesce_window_ns = 5_000;
    cfg.scale.smallbank_accounts = 200; // hot: staged plans hit moved shards
    cfg.balance_interval_ns = 100_000_000; // pinned: this test moves shards itself
    let wl = Arc::new(SmallBankWorkload::new(cfg.scale.smallbank_accounts));
    let cluster = Cluster::build_with(&cfg, wl.clone() as Arc<dyn Workload>).unwrap();
    let workload = cluster.workload.clone();
    let mut sched = FrameScheduler::new(cluster.shared.clone(), 0, 0, 0);
    let route = RouteCtx {
        router: &cluster.shared.router,
        cn: 0,
        hybrid: false,
    };

    // Every shard with a SmallBank key on it: one ping-pong round
    // invalidates every staged owner resolution at once.
    let mut shards: Vec<u16> = (0..cfg.scale.smallbank_accounts)
        .flat_map(|acc| [SAVINGS, CHECKING].map(|t| SmallBankWorkload::key(t, acc).shard()))
        .collect();
    shards.sort_unstable();
    shards.dedup();

    let mut outcomes: Vec<LaneOutcome> = Vec::new();
    let mut moved = 0usize;
    let mut next_flip = 50usize;
    while outcomes.len() < 600 {
        sched.step(&workload, &route, &mut outcomes).unwrap();
        if outcomes.len() >= next_flip {
            next_flip += 50;
            // The transfers are charged to the scheduler's own clock so
            // the interruption lands on the virtual timeline it drives.
            let mut clk = VClock(sched.now());
            for &s in &shards {
                let from = cluster.shared.router.owner_of(s);
                transfer_shard(&cluster.shared, s, from, 1 - from, &mut clk).unwrap();
                moved += 1;
            }
            sched.skip_to(clk.now());
        }
    }
    sched.finish(&mut outcomes).unwrap();

    assert!(moved > shards.len(), "the map must flip more than once");
    let bounces = cluster.shared.cn_nics[0].wrong_owner_bounces();
    assert!(
        bounces > 0,
        "mid-flight transfers must bounce some lock requests"
    );
    let commits = outcomes.iter().filter(|o| o.result.is_ok()).count();
    assert!(
        commits > 200,
        "bounced lanes must retry and commit (only {commits}/600)"
    );
    audit_books(&cluster, &wl, cfg.scale.smallbank_accounts, "bounce");
    let held: usize = cluster
        .shared
        .lock_services
        .iter()
        .map(|s| s.held_slots())
        .sum();
    assert_eq!(held, 0, "bounce-and-retry left held lock slots");
}
